"""Serve REAL models: the trained tiny-transformer family through the
threaded producer/consumer runtime (wall-clock), end to end.

    PYTHONPATH=src python examples/serve_real_models.py

Trains (or loads) five tiny classifiers, profiles them on this CPU, plans a
gear plan, then replays a bursty trace open-loop against the live server —
the same pipeline the simulator-fidelity benchmark (paper Fig. 13) uses.
"""
import numpy as np

from repro.core import HardwareSpec, SLO, optimize_gear_plan
from repro.core.simulator import trace_to_arrivals
from repro.core.traces import azure_like_trace
from repro.serving.engine import InferenceEngine, profile_engine
from repro.serving.runtime import CascadeServer, Request
from repro.serving.tinymodels import (TINY_FAMILY, apply_tiny,
                                      synthetic_classification_data,
                                      train_tiny_family,
                                      validation_record_from_scores)

ARTIFACT = "benchmarks/artifacts/tiny_family.npz"

print("loading / training the tiny model family ...")
params_by, scores_by, tok_va, lab_va = train_tiny_family(cache_path=ARTIFACT)

profiles, engines = {}, {}
for cfg in TINY_FAMILY:
    rec = validation_record_from_scores(scores_by[cfg.name], lab_va)
    eng = InferenceEngine(cfg.name,
                          lambda p, t, c=cfg: apply_tiny(c, p, t),
                          params_by[cfg.name])
    engines[cfg.name] = eng
    profiles[cfg.name] = profile_engine(eng, seq_len=32,
                                        batch_sizes=(1, 4, 16, 64),
                                        repeats=3, validation=rec)
    print(f"  {cfg.name:10s} acc={rec.accuracy:.3f} "
          f"rt(64)={profiles[cfg.name].runtime(64) * 1e3:.1f}ms")

hw = HardwareSpec(num_devices=2, mem_per_device=16e9)
plan = optimize_gear_plan(profiles, hw,
                          SLO(kind="latency", latency_p95=0.3),
                          qps_max=150, n_ranges=4).plan
for r, g in enumerate(plan.gears):
    print(f"  gear {r}: {' -> '.join(g.cascade.models)}")

trace = azure_like_trace(seconds=15, peak_qps=150, seed=3)
n = len(trace_to_arrivals(trace)) + 8
toks, labels, _ = synthetic_classification_data(n, seed=7)
requests = [Request(rid=i, tokens=toks[i]) for i in range(n)]

print("\nserving", int(trace.sum()), "requests over 15s (wall clock) ...")
server = CascadeServer(plan, engines)
done = server.run_trace(requests, trace, drain=2.0)
lats = np.array([r.latency for r in done])
acc = float(np.mean([int(r.pred == labels[r.rid]) for r in done]))
by_stage = np.bincount([r.resolver for r in done])
print(f"done: {len(done)} completed  p50={np.quantile(lats, .5) * 1e3:.1f}ms "
      f"p95={np.quantile(lats, .95) * 1e3:.1f}ms accuracy={acc:.4f}")
print(f"resolved per cascade stage: {by_stage.tolist()} "
      f"(gear switches: {len(server.gear_switches)})")
