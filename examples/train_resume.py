"""Fault-tolerant training: train a reduced assigned architecture with
checkpoints, simulate a crash, resume from LATEST.

    PYTHONPATH=src python examples/train_resume.py [--arch qwen2-moe-a2.7b]

The same driver trains the FULL configs on a TPU slice (the multi-pod
dry-run proves the production mesh compiles); remat, microbatching, ZeRO-1
and int8 DCN gradient compression are flags on the identical code path.
"""
import argparse
import shutil

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_smoke_config
from repro.models import model as M
from repro.training import (AdamWConfig, SyntheticDataset, TrainStepConfig,
                            init_opt_state, make_train_step)

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen2-moe-a2.7b")
ap.add_argument("--ckpt-dir", default="/tmp/repro_train_resume")
args = ap.parse_args()

shutil.rmtree(args.ckpt_dir, ignore_errors=True)
cfg = get_smoke_config(args.arch)
print(f"training {cfg.name} (reduced: {cfg.param_count() / 1e6:.1f}M params)")

params = M.init_params(cfg, jax.random.PRNGKey(0))
opt = init_opt_state(params)
step_fn = jax.jit(make_train_step(
    cfg, AdamWConfig(learning_rate=2e-3, warmup_steps=5, decay_steps=100),
    TrainStepConfig(remat=True, num_microbatches=2)))
ds = SyntheticDataset(cfg, batch=8, seq_len=48, seed=0)
mgr = CheckpointManager(args.ckpt_dir, keep=2)

print("\nphase 1: train 10 steps, checkpoint every 5")
for step in range(10):
    batch = {k: jnp.asarray(v) for k, v in ds.next_batch().items()}
    params, opt, m = step_fn(params, opt, batch)
    if (step + 1) % 5 == 0:
        mgr.save(step + 1, (params, opt))
    print(f"  step {step + 1:2d} loss={float(m['loss']):.4f}")

print("\n-- simulated crash: process dies, state lost --")
del params, opt

print("phase 2: restart, restore from LATEST, continue")
params = M.init_params(cfg, jax.random.PRNGKey(0))  # template
opt = init_opt_state(params)
(params, opt), meta = mgr.restore((params, opt))
params = jax.tree.map(jnp.asarray, params)
opt = jax.tree.map(jnp.asarray, opt)
print(f"  resumed at step {meta['step']}")
for step in range(meta["step"], meta["step"] + 5):
    batch = {k: jnp.asarray(v) for k, v in ds.next_batch().items()}
    params, opt, m = step_fn(params, opt, batch)
    print(f"  step {step + 1:2d} loss={float(m['loss']):.4f}")
print("\ntraining resumed seamlessly; retention kept",
      mgr.all_steps(), "checkpoints")
