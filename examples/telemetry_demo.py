"""Telemetry walkthrough (DESIGN.md §16): one flash-crowd run, fully
instrumented.

A two-gear plan serves a trace that triples its rate mid-run (a flash
crowd), with a straggler device and hedged re-issues thrown in. A
``Telemetry`` observer attached to the simulator records request spans
(admit -> queue -> execute -> escalate -> close) and feeds the metrics
registry; afterwards we print

* the span-conservation ledger (every admit accounted for),
* the latency attribution report — where each request's time went,
  broken down per gear and per 5 s window, and
* the Prometheus text endpoint output the registry would expose.

    PYTHONPATH=src python examples/telemetry_demo.py
"""
import numpy as np

from repro.core import (SLO, GearPlan, ServingSimulator, SimConfig,
                        Telemetry, make_gear, synthetic_family)
from repro.core.cascade import Cascade
from repro.core.execution import ReplayBackend
from repro.core.lp import Replica
from repro.distributed.fault_tolerance import HedgePolicy

profiles = synthetic_family(["tiny", "mini", "base"], base_runtime=2e-4,
                            runtime_ratio=2.4, base_acc=0.70, acc_gain=0.06,
                            mem_base=0.4e9, seed=3)
reps = [Replica(m, d, profiles[m].runtime_per_sample(1.0))
        for d in range(2) for m in profiles]

# two gears: an accurate heavy cascade for calm traffic and a cheap
# shallow one the scheduler downshifts to when the crowd arrives
g0 = make_gear(Cascade(("tiny", "base"), (0.35,)), reps, {"tiny": 4})
g1 = make_gear(Cascade(("tiny", "mini"), (0.2,)), reps, {"tiny": 8})
plan = GearPlan(qps_max=1200.0, gears=[g0, g1], replicas=reps,
                num_devices=2, slo=SLO(kind="latency", latency_p95=1.0))

# flash crowd: 300 qps -> 900 qps for six seconds -> back to 300
trace = np.concatenate([np.full(6, 300.0), np.full(6, 900.0),
                        np.full(6, 300.0)])
events = [(4.0, 1, "slow", 8.0), (8.0, 1, "recover", 1.0)]

telem = Telemetry()
sim = ServingSimulator(profiles, reps, 2, SimConfig(max_batch=64),
                       backend=ReplayBackend(profiles), telemetry=telem)
r = sim.run_trace(plan, trace, device_events=events,
                  hedge=HedgePolicy(hedge_multiplier=2.0))
telem.finalize()

print("1) run summary")
print(f"   completed {r.completed}/{r.offered}  shed={r.shed}  "
      f"p95={r.p95 * 1e3:.0f}ms")

print("2) span conservation (spans_closed == completed + shed)")
cons = telem.conservation()
print("   " + "  ".join(f"{k}={v}" for k, v in sorted(cons.items())))
assert cons["completed"] == r.completed

print("3) latency attribution (per gear / per 5s window)")
attr = telem.attribution(window_s=5.0)
print(Telemetry.render_attribution(attr))

print("4) Prometheus text endpoint (first 30 lines)")
for line in telem.registry.prometheus_text().splitlines()[:30]:
    print("   " + line)
