"""Serving-plane fault tolerance: device failure -> LP rebalance in
milliseconds; straggler -> hedged re-issue; capacity change -> elastic
replan (SP3+SP4 only).

    PYTHONPATH=src python examples/fault_tolerance_demo.py
"""
import time

import numpy as np

from repro.core import (HardwareSpec, SLO, ServingSimulator,
                        optimize_gear_plan, synthetic_family)
from repro.core.traces import diurnal_like_trace
from repro.distributed.fault_tolerance import (HedgePolicy,
                                               rebalance_on_failure)

profiles = synthetic_family(["tiny", "mini", "small", "medium", "base"],
                            base_runtime=2e-4, runtime_ratio=2.4,
                            base_acc=0.70, acc_gain=0.05, mem_base=0.4e9,
                            seed=3)
hw = HardwareSpec(num_devices=4, mem_per_device=16e9)
plan = optimize_gear_plan(profiles, hw,
                          SLO(kind="latency", latency_p95=0.4),
                          qps_max=6000, n_ranges=8).plan
sim = ServingSimulator(profiles, plan.replicas, hw.num_devices)
trace = diurnal_like_trace(seconds=60, peak_qps=4500, seed=5)

print("1) baseline")
r = sim.run_trace(plan, trace)
print(f"   completed {r.completed}/{r.offered}  p95={r.p95 * 1e3:.0f}ms")

print("2) device 0 dies at t=20s, NO mitigation")
events = [(20.0, 0, "fail", 0.0)]
r = sim.run_trace(plan, trace, device_events=events)
print(f"   completed {r.completed}/{r.offered}  p95={r.p95 * 1e3:.0f}ms  "
      f"({r.offered - r.completed} requests stranded)")

print("3) same failure, LP rebalance on failure")
times = []

def on_fail(t, dev):
    t0 = time.time()
    gears = rebalance_on_failure(plan, profiles, {dev}).gears
    times.append((time.time() - t0) * 1e3)
    return gears

r = sim.run_trace(plan, trace, device_events=events, on_failure=on_fail)
print(f"   completed {r.completed}/{r.offered}  p95={r.p95 * 1e3:.0f}ms  "
      f"(rebalance took {times[0]:.1f}ms — no model loading)")

print("4) straggler: device 1 runs 8x slow for 20s, hedged re-issue")
ev = [(20.0, 1, "slow", 8.0), (40.0, 1, "recover", 1.0)]
lo = diurnal_like_trace(seconds=60, peak_qps=2500, seed=5)
r_plain = sim.run_trace(plan, lo, device_events=ev)
r_hedge = sim.run_trace(plan, lo, device_events=ev,
                        hedge=HedgePolicy(hedge_multiplier=2.5))
print(f"   p99 {r_plain.latency_quantile(.99) * 1e3:.0f}ms -> "
      f"{r_hedge.latency_quantile(.99) * 1e3:.0f}ms with hedging")
