"""Quickstart: register a model family, plan a gear plan, serve a trace.

    PYTHONPATH=src python examples/quickstart.py

This is CascadeServe's whole lifecycle (paper Fig. 3) in ~40 lines:
offline — profile models, generate the gear plan (Algorithm 1);
online  — measure QPS, switch gears, cascade certainty-gated inferences.
"""
import numpy as np

from repro.core import (HardwareSpec, SLO, ServingSimulator,
                        optimize_gear_plan, synthetic_family)
from repro.core.traces import diurnal_like_trace

# 1. Register a model family (here: a calibrated synthetic BERT-like family;
#    see examples/serve_real_models.py for real, trained models).
profiles = synthetic_family(
    ["tiny", "mini", "small", "medium", "base"],
    base_runtime=2e-4, runtime_ratio=2.4, base_acc=0.70, acc_gain=0.05,
    mem_base=0.4e9, seed=3)
for name, p in profiles.items():
    print(f"  {name:8s} accuracy={p.accuracy:.3f} "
          f"latency(b=1)={p.runtime(1) * 1e3:.2f}ms")

# 2. Offline: generate the gear plan for your hardware and SLO.
hw = HardwareSpec(num_devices=4, mem_per_device=16e9)
slo = SLO(kind="latency", latency_p95=0.4)   # p95 <= 400ms, maximise acc
report = optimize_gear_plan(profiles, hw, slo, qps_max=7600, n_ranges=8)
plan = report.plan
print(f"\nplanned in {report.wall_seconds:.1f}s "
      f"({report.submodule_calls} submodule calls, "
      f"{report.errors_resolved} errors resolved)")
for r, gear in enumerate(plan.gears):
    print(f"  <= {plan.range_width * (r + 1):5.0f} qps: "
          f"{' -> '.join(gear.cascade.models):30s} "
          f"acc={gear.expected_accuracy:.3f} "
          f"p95={gear.expected_p95 * 1e3:.0f}ms")

# 3. Online: serve a bursty diurnal trace (simulated here; the identical
#    plan drives the real threaded runtime in serve_real_models.py).
trace = diurnal_like_trace(seconds=60, peak_qps=7600, seed=5)
sim = ServingSimulator(profiles, plan.replicas, hw.num_devices)
res = sim.run_trace(plan, trace)
print(f"\nserved {res.completed}/{res.offered} requests: "
      f"p95={res.p95 * 1e3:.0f}ms accuracy={res.accuracy:.4f} "
      f"gear switches={len(res.gear_switches)} "
      f"SLO {'MET' if res.p95 <= 0.4 else 'VIOLATED'}")

# save / reload the plan (ops handoff)
js = plan.to_json()
from repro.core import GearPlan
assert GearPlan.from_json(js).n_ranges == plan.n_ranges
print("gear plan serialises to JSON ->", len(js), "bytes")
