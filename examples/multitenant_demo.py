"""Multi-tenant serving walkthrough (DESIGN.md §11).

Two tenants with different latency SLOs share one CascadeServe fleet:
joint placement, per-tenant gear ladders, admission control, and
per-tenant background re-planning. The scenario sends the interactive
tenant a flash crowd at 2.5x its planned ``qps_max`` while the batch
tenant idles at half load — the shared fleet lends the idle headroom to
the crowd, the admission controller sheds only what genuinely cannot be
served within the deadline, and the drifted tenant's ladder is re-planned
in the background without touching the other tenant or the placement.

    PYTHONPATH=src python examples/multitenant_demo.py
"""
import numpy as np

from repro.core import (AdmissionConfig, AdmissionController, HardwareSpec,
                        MonitorConfig, SLO, ServingSimulator, SimConfig,
                        TenantSpec, make_tenant_lifecycles,
                        plan_multi_tenant)
from repro.core.profiles import synthetic_family


def main():
    profiles = synthetic_family(["small", "mid", "large"],
                                base_runtime=2e-3, runtime_ratio=2.4,
                                base_acc=0.72, acc_gain=0.06,
                                mem_base=0.4e9, seed=5)
    hw = HardwareSpec(num_devices=4, mem_per_device=16e9)
    tenants = [
        TenantSpec("interactive", SLO(kind="latency", latency_p95=0.35),
                   qps_max=600.0, weight=2.0, n_ranges=4),
        TenantSpec("batch", SLO(kind="latency", latency_p95=1.0),
                   qps_max=600.0, weight=1.0, n_ranges=4),
    ]

    print("== planning: solo passes -> joint placement -> pinned ladders")
    report = plan_multi_tenant(profiles, hw, tenants)
    mt = report.plan
    print(f"   planned in {report.wall_seconds:.1f}s; shared placement:")
    by_dev = {}
    for r in mt.replicas:
        by_dev.setdefault(r.device, []).append(r.model)
    for d in sorted(by_dev):
        print(f"     device {d}: {by_dev[d]}")
    for name in mt.names:
        plan = mt.plans[name]
        print(f"   {name}: {plan.n_ranges} gears over qps_max "
              f"{plan.qps_max:.0f}; top-range cascade: "
              f"{plan.gears[-1].cascade}")

    # flash crowd on the interactive tenant; batch idles at half load
    crowd = np.concatenate([np.full(5, 360.0), np.full(10, 1500.0),
                            np.full(5, 360.0)])
    steady = np.full(20, 300.0)
    traces = {"interactive": crowd, "batch": steady}

    print("\n== serving: flash crowd at 2.5x the planned range")
    admission = AdmissionController(mt,
                                    AdmissionConfig(utilization_cap=0.75))
    # tv_min_ticks past the demo horizon: the 20s window is too short to
    # judge the batch tenant's time-in-range distribution against its
    # prior — only the flash crowd's qps-exceeds-range should trigger here
    lifecycles = make_tenant_lifecycles(
        report, profiles, hw,
        monitor_cfg=MonitorConfig(qps_sustain_ticks=5, cooldown=30.0,
                                  tv_min_ticks=1000),
        plan_latency=1.0)
    sim = ServingSimulator(profiles, mt.replicas, hw.num_devices,
                           SimConfig())
    results = sim.run_multi_tenant(mt, traces, admission=admission,
                                   lifecycles=lifecycles)

    print(f"   {'tenant':<12} {'offered':>8} {'served':>8} {'shed':>6} "
          f"{'shed%':>6} {'p95 ms':>7} {'SLO ms':>7} {'acc':>6}")
    for spec in tenants:
        r = results[spec.name]
        print(f"   {spec.name:<12} {r.offered:>8} "
              f"{r.result.completed:>8} {r.shed:>6} "
              f"{100 * r.shed_rate:>5.1f}% {r.p95 * 1e3:>7.0f} "
              f"{spec.slo.latency_p95 * 1e3:>7.0f} {r.accuracy:>6.3f}")

    print("\n== admission + re-planning activity")
    for spec in tenants:
        lc = lifecycles[spec.name]
        trig = [t.reason for t in lc.triggers]
        swaps = [(f"t={s.t:.1f}s", f"epoch {s.epoch}", s.reason)
                 for s in lc.swaps]
        print(f"   {spec.name}: triggers={trig or 'none'} "
              f"swaps={swaps or 'none'}")
    drifted = lifecycles["interactive"]
    if drifted.swaps:
        new_plan = drifted.active.plan
        same = [(a.model, a.device) for a in new_plan.replicas] == \
            [(b.model, b.device) for b in mt.replicas]
        print(f"   interactive re-planned to qps_max "
              f"{new_plan.qps_max:.0f} with placement "
              f"{'PINNED (unchanged)' if same else 'MOVED (bug!)'}")
    print("   batch tenant's plan untouched:",
          lifecycles["batch"].active.plan is mt.plans["batch"])


if __name__ == "__main__":
    main()
