"""Deterministic example generators for the vendored hypothesis stub.

Each strategy draws boundary values for the first examples (hypothesis'
own heuristic: bugs live at the edges) and seeded-random values after,
via ``example(rng, i)`` where ``i`` is the example index within one test.
"""
import numpy as np


class SearchStrategy:
    def example(self, rng: np.random.Generator, i: int):
        raise NotImplementedError


class _Integers(SearchStrategy):
    def __init__(self, min_value, max_value):
        self.lo = int(min_value)
        self.hi = int(max_value)

    def example(self, rng, i):
        if i == 0:
            return self.lo
        if i == 1:
            return self.hi
        return int(rng.integers(self.lo, self.hi + 1))


class _Floats(SearchStrategy):
    def __init__(self, min_value, max_value):
        self.lo = float(min_value)
        self.hi = float(max_value)

    def example(self, rng, i):
        if i == 0:
            return self.lo
        if i == 1:
            return self.hi
        return float(rng.uniform(self.lo, self.hi))


class _Lists(SearchStrategy):
    def __init__(self, elements, min_size=0, max_size=10):
        self.elements = elements
        self.min_size = int(min_size)
        self.max_size = int(max_size)

    def example(self, rng, i):
        if i == 0:
            size = self.min_size
        elif i == 1:
            size = self.max_size
        else:
            size = int(rng.integers(self.min_size, self.max_size + 1))
        return [self.elements.example(rng, i + 2 + j)
                for j in range(size)]


def integers(min_value=0, max_value=2 ** 31 - 1):
    return _Integers(min_value, max_value)


def floats(min_value=0.0, max_value=1.0, **_ignored):
    return _Floats(min_value, max_value)


def lists(elements, min_size=0, max_size=10, **_ignored):
    return _Lists(elements, min_size, max_size)
