"""Minimal vendored stand-in for the slice of the hypothesis API this test
suite uses (``given``, ``settings``, ``strategies.integers/floats/lists``).

The real hypothesis cannot be installed in the hermetic CI container, and
``pytest.importorskip("hypothesis")`` was silently skipping five property-
test modules there. ``tests/conftest.py`` puts ``tests/_compat`` on
``sys.path`` ONLY when the real package is absent, so an environment with
hypothesis installed (e.g. a developer laptop) keeps the real engine —
shrinking, the example database, coverage-guided generation — and this stub
only restores *execution* where there would otherwise be none.

Semantics: ``@given`` turns the test into a deterministic loop of
``max_examples`` examples (from ``@settings``, default 20). Example
streams are seeded per test name, boundary values first, so failures
reproduce exactly. NOTE: the wrapper deliberately avoids
``functools.wraps`` — copying ``__wrapped__``/signature metadata makes
pytest mistake the strategy parameters for fixtures.
"""
import zlib

import numpy as np

from . import strategies  # noqa: F401  (re-export: `from hypothesis import strategies`)

__version__ = "0.0-stub"


def settings(max_examples=20, deadline=None, **_ignored):
    """Record run parameters on the function; ``given`` reads them lazily,
    so the decorator order (@given/@settings) does not matter."""
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(*arg_strategies, **kw_strategies):
    def deco(fn):
        def wrapper():
            n = getattr(wrapper, "_stub_max_examples",
                        getattr(fn, "_stub_max_examples", 20))
            seed = zlib.crc32(getattr(fn, "__name__", "test").encode())
            rng = np.random.default_rng(seed)
            for i in range(n):
                args = [s.example(rng, i) for s in arg_strategies]
                kwargs = {k: s.example(rng, i)
                          for k, s in kw_strategies.items()}
                fn(*args, **kwargs)
        wrapper.__name__ = getattr(fn, "__name__", "wrapped_test")
        wrapper.__doc__ = getattr(fn, "__doc__", None)
        wrapper.is_hypothesis_stub = True
        return wrapper
    return deco
