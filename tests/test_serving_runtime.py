"""Real serving runtime + baselines + engine bucketing."""
import numpy as np
import pytest

from repro.core import HardwareSpec, SLO, ServingSimulator
from repro.core.simulator import trace_to_arrivals
from repro.serving.baselines import (CocktailPlusPolicy, DynBaPolicy,
                                     MSPlusPolicy)


def test_engine_bucketing_and_padding():
    import jax.numpy as jnp
    from repro.serving.engine import InferenceEngine
    calls = []

    def apply_fn(params, tokens):
        calls.append(tokens.shape[0])
        return jnp.zeros((tokens.shape[0], 2))

    eng = InferenceEngine("x", apply_fn, {}, buckets=(1, 2, 4, 8))
    out = eng.infer(np.zeros((3, 16), np.int32))
    assert out.shape == (3, 2)
    assert calls[-1] == 4  # padded to the 4-bucket
    out = eng.infer(np.zeros((13, 16), np.int32))  # oversize: split 8 + 8pad
    assert out.shape == (13, 2)


def test_dynba_policy(bert_like_profiles):
    hw = HardwareSpec(num_devices=2, mem_per_device=16e9)
    pol = DynBaPolicy(model="medium")
    gears, sel, reps, nd = pol.build(bert_like_profiles, hw,
                                     SLO(kind="latency", latency_p95=0.4),
                                     2000)
    assert len(gears) == 1
    sim = ServingSimulator(bert_like_profiles, reps, nd)
    res = sim.run_policy(gears, sel, np.full(10, 200.0))
    assert res.stable
    assert res.accuracy == pytest.approx(
        bert_like_profiles["medium"].accuracy, abs=0.02)


def test_msplus_switches_models(bert_like_profiles):
    hw = HardwareSpec(num_devices=2, mem_per_device=16e9)
    pol = MSPlusPolicy(n_ranges=6)
    gears, sel, reps, nd = pol.build(bert_like_profiles, hw,
                                     SLO(kind="latency", latency_p95=0.4),
                                     6000)
    # low range uses a more accurate model than the top range
    lo = gears[0].cascade.models[0]
    hi = gears[-1].cascade.models[0]
    assert bert_like_profiles[lo].accuracy >= \
        bert_like_profiles[hi].accuracy
    trace = np.concatenate([np.full(10, 100.0), np.full(10, 5500.0)])
    sim = ServingSimulator(bert_like_profiles, reps, nd)
    res = sim.run_policy(gears, sel, trace)
    assert len(res.gear_switches) >= 1


def test_cocktail_autoscales(bert_like_profiles):
    hw = HardwareSpec(num_devices=4, mem_per_device=16e9)
    trace = np.concatenate([np.full(15, 50.0), np.full(15, 900.0),
                            np.full(15, 50.0)])
    pol = CocktailPlusPolicy(scale_interval=5.0, target_util=0.7,
                             forecast=trace)
    gears, sel, reps, nd = pol.build(bert_like_profiles, hw,
                                     SLO(kind="latency", latency_p95=0.4),
                                     1000)
    sim = ServingSimulator(bert_like_profiles, reps, nd)
    res = sim.run_policy(gears, sel, trace)
    cost = CocktailPlusPolicy.active_device_cost(res, gears)
    assert 1.0 <= cost <= hw.num_devices
    assert len(res.gear_switches) >= 1  # it scaled


@pytest.mark.slow
def test_real_runtime_tiny_models(tmp_path):
    """End-to-end REAL serving: threaded producer/consumer over jitted tiny
    models, cascade semantics verified on wall clock."""
    import jax
    from repro.core import HardwareSpec, SLO, optimize_gear_plan
    from repro.serving.engine import InferenceEngine, profile_engine
    from repro.serving.runtime import CascadeServer, Request
    from repro.serving.tinymodels import (TINY_FAMILY, apply_tiny,
                                          synthetic_classification_data,
                                          train_tiny_family,
                                          validation_record_from_scores)
    fam = TINY_FAMILY[:3]
    params_by, scores_by, tok_va, lab_va = train_tiny_family(
        n_train=1024, n_val=512, steps_scale=0.3, family=fam,
        cache_path="benchmarks/artifacts/tiny_family_test.npz")
    profiles = {}
    engines = {}
    for cfg in fam:
        rec = validation_record_from_scores(scores_by[cfg.name], lab_va)
        eng = InferenceEngine(cfg.name,
                              lambda p, t, c=cfg: apply_tiny(c, p, t),
                              params_by[cfg.name])
        engines[cfg.name] = eng
        profiles[cfg.name] = profile_engine(
            eng, 32, batch_sizes=(1, 4, 16), repeats=2, validation=rec)
    hw = HardwareSpec(num_devices=2, mem_per_device=16e9)
    plan = optimize_gear_plan(profiles, hw,
                              SLO(kind="latency", latency_p95=0.5),
                              qps_max=300, n_ranges=4).plan
    trace = np.full(4, 60.0)
    n = int(trace.sum()) + 4
    toks, labels, _ = synthetic_classification_data(n, seed=7)
    reqs = [Request(rid=i, tokens=toks[i]) for i in range(n)]
    server = CascadeServer(plan, engines)
    done = server.run_trace(reqs, trace, drain=2.0)
    assert len(done) >= 0.95 * len(trace_to_arrivals(trace))
    lats = np.array([r.latency for r in done])
    assert np.quantile(lats, 0.95) < 1.0
    acc = np.mean([int(r.pred == labels[r.rid]) for r in done])
    assert acc > 0.5
