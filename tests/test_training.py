"""Training substrate: AdamW reference check, microbatch equivalence,
loss-goes-down integration, data pipeline determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.training import (AdamWConfig, SyntheticDataset, TrainStepConfig,
                            adamw_update, init_opt_state, make_train_step)
from repro.training.optimizer import lr_schedule, opt_state_pspecs


def test_adamw_matches_manual_reference():
    cfg = AdamWConfig(learning_rate=1e-2, b1=0.9, b2=0.99, eps=1e-8,
                      weight_decay=0.0, grad_clip_norm=1e9,
                      warmup_steps=0, decay_steps=10 ** 9, min_lr_ratio=1.0)
    params = {"w": jnp.asarray([1.0, -2.0], jnp.float32)}
    grads = {"w": jnp.asarray([0.5, 0.1], jnp.float32)}
    state = init_opt_state(params)
    new_p, new_s, _ = adamw_update(params, grads, state, cfg)
    # manual step-1 adam with bias correction
    m = 0.1 * np.array([0.5, 0.1])
    v = 0.01 * np.array([0.25, 0.01])
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.99)
    expect = np.array([1.0, -2.0]) - 1e-2 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), expect, rtol=1e-5)
    assert int(new_s["step"]) == 1


def test_weight_decay_skips_norms():
    cfg = AdamWConfig(learning_rate=1e-2, weight_decay=0.5,
                      grad_clip_norm=1e9, warmup_steps=0,
                      decay_steps=10 ** 9, min_lr_ratio=1.0)
    params = {"w": jnp.ones((2,)), "norm": {"scale": jnp.ones((2,))}}
    grads = jax.tree.map(jnp.zeros_like, params)
    new_p, _, _ = adamw_update(params, grads, init_opt_state(params), cfg)
    assert float(new_p["w"][0]) < 1.0          # decayed
    assert float(new_p["norm"]["scale"][0]) == 1.0  # not decayed


def test_grad_clipping():
    cfg = AdamWConfig(learning_rate=0.0, grad_clip_norm=1.0,
                      warmup_steps=0, decay_steps=10 ** 9)
    params = {"w": jnp.zeros((3,))}
    grads = {"w": jnp.asarray([10.0, 0.0, 0.0])}
    _, _, metrics = adamw_update(params, grads, init_opt_state(params), cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(10.0)


def test_lr_schedule_shape():
    cfg = AdamWConfig(learning_rate=1.0, warmup_steps=10, decay_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(lr_schedule(cfg, jnp.asarray(s))) for s in
           [0, 5, 10, 55, 100, 200]]
    assert lrs[1] == pytest.approx(0.5)     # mid-warmup
    assert lrs[2] == pytest.approx(1.0)     # peak
    assert lrs[3] < 1.0                     # decaying
    assert lrs[4] == pytest.approx(0.1, abs=1e-6)  # floor
    assert lrs[5] == pytest.approx(0.1, abs=1e-6)


def test_microbatch_equivalence():
    """Grad accumulation over 2 microbatches == full batch (same update)."""
    cfg = get_smoke_config("olmo-1b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    opt = init_opt_state(params)
    ds = SyntheticDataset(cfg, batch=8, seq_len=32, seed=0)
    batch = {k: jnp.asarray(v) for k, v in ds.next_batch().items()}
    ocfg = AdamWConfig(learning_rate=1e-3, warmup_steps=0, decay_steps=100)
    s1 = make_train_step(cfg, ocfg, TrainStepConfig(remat=False,
                                                    num_microbatches=1))
    s2 = make_train_step(cfg, ocfg, TrainStepConfig(remat=False,
                                                    num_microbatches=2))
    p1, _, m1 = jax.jit(s1)(params, opt, batch)
    p2, _, m2 = jax.jit(s2)(params, opt, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
    d = max(float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert d < 5e-5


def test_loss_decreases_end_to_end():
    cfg = get_smoke_config("qwen2-0.5b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(
        cfg, AdamWConfig(learning_rate=2e-3, warmup_steps=5,
                         decay_steps=100),
        TrainStepConfig(remat=True)))
    ds = SyntheticDataset(cfg, batch=8, seq_len=48, seed=0)
    losses = []
    for _ in range(20):
        b = {k: jnp.asarray(v) for k, v in ds.next_batch().items()}
        params, opt, m = step(params, opt, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5


def test_zero1_pspec_expansion():
    from jax.sharding import PartitionSpec as P
    pspecs = {"w": P(None, "model"), "b": P("model")}
    ospecs = opt_state_pspecs(pspecs, zero1_axis="pod")
    assert ospecs["m"]["w"] == P("pod", "model")
    assert ospecs["m"]["b"] == P("model")  # already fully sharded
    assert ospecs["step"] == P()


def test_synthetic_data_deterministic_and_learnable():
    cfg = get_smoke_config("olmo-1b")
    a = SyntheticDataset(cfg, batch=4, seq_len=32, seed=7).next_batch()
    b = SyntheticDataset(cfg, batch=4, seq_len=32, seed=7).next_batch()
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # labels are the next-token shift of the same stream
    ds = SyntheticDataset(cfg, batch=2, seq_len=16, seed=1)
    batch = ds.next_batch()
    assert batch["tokens"].shape == (2, 16)
    assert batch["labels"].shape == (2, 16)
    np.testing.assert_array_equal(batch["tokens"][:, 1:],
                                  batch["labels"][:, :-1])
