"""Algorithm 1 end-to-end: plans satisfy the SLO in simulation; errors
propagate; infeasibility is reported; plans round-trip through JSON."""
import numpy as np
import pytest

from repro.core import (GearPlan, HardwareSpec, InfeasiblePlanError, SLO,
                        ServingSimulator, optimize_gear_plan)
from repro.core.traces import diurnal_like_trace, zipf_prior


def test_latency_slo_plan(small_plan, bert_like_profiles):
    report, hw = small_plan
    plan = report.plan
    assert plan.n_ranges == 8
    # high-QPS ranges get faster (cheaper) cascades than low-QPS ranges
    acc = [g.expected_accuracy for g in plan.gears]
    assert acc[0] >= acc[-1]
    # every gear respects the latency SLO in planning
    assert all(g.expected_p95 <= 0.4 + 1e-6 for g in plan.gears)
    # trace simulation meets the SLO
    sim = ServingSimulator(bert_like_profiles, plan.replicas, hw.num_devices)
    trace = diurnal_like_trace(seconds=60, peak_qps=7600, seed=5)
    res = sim.run_trace(plan, trace)
    assert res.stable
    assert res.p95 <= 0.4
    assert res.accuracy > bert_like_profiles["tiny"].accuracy


def test_accuracy_slo_plan(bert_like_profiles):
    hw = HardwareSpec(num_devices=4, mem_per_device=16e9)
    slo = SLO(kind="accuracy", min_accuracy=0.93)
    report = optimize_gear_plan(bert_like_profiles, hw, slo, qps_max=5000,
                                n_ranges=8)
    plan = report.plan
    prior = zipf_prior(8)
    weighted = float(sum(g.expected_accuracy * w
                         for g, w in zip(plan.gears, prior)))
    assert weighted >= 0.93 - 1e-6


def test_infeasible_raises(bert_like_profiles):
    hw = HardwareSpec(num_devices=1, mem_per_device=16e9)
    slo = SLO(kind="latency", latency_p95=0.05)
    with pytest.raises(InfeasiblePlanError):
        optimize_gear_plan(bert_like_profiles, hw, slo, qps_max=500000,
                           n_ranges=4)


def test_memory_constraint_respected(bert_like_profiles):
    hw = HardwareSpec(num_devices=4, mem_per_device=16e9)
    slo = SLO(kind="latency", latency_p95=0.4)
    report = optimize_gear_plan(bert_like_profiles, hw, slo, qps_max=5000,
                                n_ranges=6)
    mem = np.zeros(hw.num_devices)
    for r in report.plan.replicas:
        mem[r.device] += bert_like_profiles[r.model].mem_bytes
    assert (mem <= hw.mem_per_device + 1e-6).all()


def test_every_used_model_has_a_replica(small_plan):
    report, _ = small_plan
    plan = report.plan
    placed = {r.model for r in plan.replicas}
    for g in plan.gears:
        for m in g.cascade.models:
            assert m in placed


def test_load_fractions_normalised(small_plan):
    report, _ = small_plan
    for g in report.plan.gears:
        for m, fr in g.load_fractions.items():
            assert sum(fr.values()) == pytest.approx(1.0, abs=1e-6)


def test_plan_json_roundtrip(small_plan):
    report, _ = small_plan
    plan = report.plan
    plan2 = GearPlan.from_json(plan.to_json())
    assert plan2.qps_max == plan.qps_max
    assert len(plan2.gears) == len(plan.gears)
    for g1, g2 in zip(plan.gears, plan2.gears):
        assert g1.cascade == g2.cascade
        assert g1.min_queue_lens == g2.min_queue_lens
    assert [(r.model, r.device) for r in plan2.replicas] == \
        [(r.model, r.device) for r in plan.replicas]


def test_gear_lookup_boundaries(small_plan):
    report, _ = small_plan
    plan = report.plan
    assert plan.gear_index_for_qps(0.0) == 0
    assert plan.gear_index_for_qps(plan.qps_max * 2) == plan.n_ranges - 1
    w = plan.range_width
    assert plan.gear_index_for_qps(w * 2.5) == 2


def test_planner_beats_random_assignment(bert_like_profiles):
    """Fig.-10 flavour: the planner's plan dominates a random one."""
    hw = HardwareSpec(num_devices=4, mem_per_device=16e9)
    slo = SLO(kind="latency", latency_p95=0.4)
    report = optimize_gear_plan(bert_like_profiles, hw, slo, qps_max=6000,
                                n_ranges=6, seed=0)
    plan = report.plan
    sim = ServingSimulator(bert_like_profiles, plan.replicas,
                           hw.num_devices)
    trace = diurnal_like_trace(seconds=40, peak_qps=6000, seed=9)
    res = sim.run_trace(plan, trace)

    # random plan: same placement, random single-model gears
    import copy
    rng = np.random.default_rng(0)
    rnd = copy.deepcopy(plan)
    from repro.core.cascade import Cascade
    from repro.core.gears import uniform_load_fractions
    models = list(bert_like_profiles)
    for g in rnd.gears:
        m = models[rng.integers(len(models))]
        g.cascade = Cascade((m,), ())
        g.min_queue_lens = {m: 1}
        g.load_fractions = uniform_load_fractions(rnd.replicas, (m,))
    res_rnd = sim.run_trace(rnd, trace)
    ok = res.p95 <= 0.4
    rnd_worse = (res_rnd.p95 > 0.4 or not res_rnd.stable
                 or res_rnd.accuracy <= res.accuracy + 0.005)
    assert ok and rnd_worse


def test_elastic_replan_grow(bert_like_profiles):
    from repro.core.planner import make_state
    from repro.core.plan_state import OK
    from repro.core.submodules import SUBMODULES
    from repro.distributed.fault_tolerance import elastic_replan
    hw = HardwareSpec(num_devices=3, mem_per_device=16e9)
    state = make_state(bert_like_profiles, hw,
                       SLO(kind="latency", latency_p95=0.4), 5000, 6)
    error, cur = OK, 0
    for _ in range(200):
        error, state = SUBMODULES[cur](error, state)
        if error.is_ok:
            cur = (cur + 1) % 4
            if cur == 0 and state.min_qlens:
                break
        else:
            cur -= 1
    bigger = elastic_replan(state, 6)
    assert bigger.hardware.num_devices == 6
    assert len(bigger.replicas) >= len(state.replicas)
    assert max(bigger.util) <= max(state.util) + 1e-6
