"""Golden behavior-fingerprint regression test.

The discrete-event simulator is the planner's ground truth (DESIGN.md §4),
so its *behavior* — not just its API — must be frozen: a refactor that
shifts one routing draw or one batch boundary silently re-tunes every plan
the repo produces. This test replays five canonical scenarios (fixed-rate,
trace-driven gear switching, ensemble voting, device failure + recovery,
hedged stragglers) and asserts the scalar outcomes are **bit-identical** to
the committed fingerprint in ``tests/data/behavior_fingerprint.json``.

Regenerating after an INTENTIONAL behavior change
-------------------------------------------------
Run the test module with the regen flag and commit the diff alongside the
change that explains it::

    PYTHONPATH=src REGEN_FINGERPRINT=1 python -m pytest \
        tests/test_behavior_fingerprint.py -q

The JSON then shows reviewers exactly which scenarios moved and by how
much; an unexplained diff is a bug, not noise (the simulator is seeded and
deterministic end to end). CI uploads this file as an artifact on failure
so golden mismatches are inspectable without a local checkout.
"""
import json
import os

import numpy as np
import pytest

from repro.core.cascade import Cascade
from repro.core.gears import GearPlan, SLO
from repro.core.lp import Replica
from repro.core.profiles import synthetic_family
from repro.core.simulator import ServingSimulator, SimConfig, make_gear
from repro.distributed.fault_tolerance import HedgePolicy

FINGERPRINT_PATH = os.path.join(os.path.dirname(__file__), "data",
                                "behavior_fingerprint.json")


def _family():
    return synthetic_family(["tiny", "mini", "base"], base_runtime=2e-4,
                            runtime_ratio=2.4, base_acc=0.70, acc_gain=0.06,
                            mem_base=0.4e9, seed=3)


def _plan(profiles, reps):
    g0 = make_gear(Cascade(("tiny", "base"), (0.35,)), reps, {"tiny": 2})
    g1 = make_gear(Cascade(("tiny", "mini"), (0.2,)), reps, {"tiny": 4})
    g2 = make_gear(Cascade(("tiny",), ()), reps, {"tiny": 8})
    return GearPlan(qps_max=600.0, gears=[g0, g1, g2], replicas=reps,
                    num_devices=2, slo=SLO(kind="latency", latency_p95=1.0))


def _summarize(res):
    """Scalar digest of one run. Floats are stored via repr round-trip, so
    equality below is bit-equality of the underlying doubles."""
    return {
        "completed": int(res.completed),
        "offered": int(res.offered),
        "backlog_end": int(res.backlog_end),
        "p95": float(res.p95),
        "accuracy": float(res.accuracy),
        "switches": len(res.gear_switches),
        "busy": float(res.device_busy.sum()),
    }


def compute_fingerprint():
    profiles = _family()
    reps = [Replica(m, d, profiles[m].runtime_per_sample(1.0))
            for d in range(2) for m in profiles]
    plan = _plan(profiles, reps)
    sim = ServingSimulator(profiles, reps, 2, SimConfig(max_batch=128))

    out = {}

    # 1. fixed-rate: constant arrivals, single gear (the planner's view)
    out["fixed-rate"] = _summarize(
        sim.run_fixed(plan.gears[0], qps=300.0, horizon=3.0))

    # 2. trace: load step up and back down -> §5 producer switches gears
    trace = np.concatenate([np.full(3, 60.0), np.full(3, 550.0),
                            np.full(4, 60.0)])
    out["trace"] = _summarize(sim.run_trace(plan, trace))

    # 3. ensemble: all members vote, majority decides (Cocktail+ mode)
    ens = make_gear(Cascade(("tiny", "mini", "base"), (0.0, 0.0)), reps,
                    mode="ensemble")
    ens_plan = GearPlan(qps_max=600.0, gears=[ens], replicas=reps,
                        num_devices=2, slo=plan.slo)
    out["ensemble"] = _summarize(
        sim.run_trace(ens_plan, np.full(4, 80.0)))

    # 4. device-failure: kill device 0 mid-trace, recover during drain
    ev = [(2.0, 0, "fail", 0.0), (9.0, 0, "recover", 1.0)]
    out["device-failure"] = _summarize(
        sim.run_trace(plan, np.full(8, 50.0), device_events=ev, drain=3.0))

    # 5. hedging: a straggling device + hedged re-issues on siblings
    ev = [(1.0, 1, "slow", 5.0), (6.0, 1, "recover", 1.0)]
    out["hedging"] = _summarize(
        sim.run_trace(plan, np.full(8, 60.0), device_events=ev, drain=3.0,
                      hedge=HedgePolicy(hedge_multiplier=3.0)))
    return out


def test_simulator_matches_golden_fingerprint():
    fresh = compute_fingerprint()
    if os.environ.get("REGEN_FINGERPRINT"):
        os.makedirs(os.path.dirname(FINGERPRINT_PATH), exist_ok=True)
        with open(FINGERPRINT_PATH, "w") as f:
            json.dump(fresh, f, indent=1, sort_keys=True)
            f.write("\n")
        pytest.skip(f"fingerprint regenerated at {FINGERPRINT_PATH}")
    assert os.path.exists(FINGERPRINT_PATH), \
        "no golden fingerprint committed; run with REGEN_FINGERPRINT=1"
    with open(FINGERPRINT_PATH) as f:
        golden = json.load(f)
    assert fresh == golden, (
        "simulator behavior drifted from the golden fingerprint; if the "
        "change is intentional, regenerate with REGEN_FINGERPRINT=1 and "
        "commit the JSON diff with an explanation")
