"""Multi-tenant serving (core/tenancy.py): per-tenant gear plans over one
shared placement, tenant determinism, executor parity, per-tenant
re-planning, and the serialization round trips for the tenant types."""
import numpy as np
import pytest

from repro.core import (DecisionTrace, HardwareSpec, RoutePool, SLO,
                        ServingSimulator, SimConfig)
from repro.core.gears import Gear, GearPlan
from repro.core.lp import Replica
from repro.core.cascade import Cascade
from repro.core.simulator import make_gear
from repro.core.tenancy import (MultiTenantPlan, TenantSpec,
                                effective_trigger, make_tenant_lifecycles,
                                merge_tenant_arrivals, plan_multi_tenant,
                                single_tenant_plan)


@pytest.fixture(scope="module")
def small_family():
    from repro.core.profiles import synthetic_family
    return synthetic_family(["tiny", "small", "base"], base_runtime=2e-4,
                            runtime_ratio=2.4, base_acc=0.70,
                            acc_gain=0.06, mem_base=0.4e9, seed=3)


@pytest.fixture(scope="module")
def two_tenants():
    return [
        TenantSpec("interactive", SLO(kind="latency", latency_p95=0.5),
                   qps_max=400.0, weight=2.0, n_ranges=2),
        TenantSpec("analytics", SLO(kind="latency", latency_p95=1.0),
                   qps_max=200.0, weight=1.0, n_ranges=2),
    ]


@pytest.fixture(scope="module")
def mt_report(small_family, two_tenants):
    hw = HardwareSpec(num_devices=2, mem_per_device=16e9)
    return plan_multi_tenant(small_family, hw, two_tenants), hw


# ---------------------------------------------------------------------------
# Validation + serialization (satellite: ValueErrors + tenant round trips)
# ---------------------------------------------------------------------------

def test_slo_validation_raises_valueerror():
    with pytest.raises(ValueError, match="kind"):
        SLO(kind="throughput")
    with pytest.raises(ValueError, match="latency_p95"):
        SLO(kind="latency")
    with pytest.raises(ValueError, match="positive"):
        SLO(kind="latency", latency_p95=-0.1)
    with pytest.raises(ValueError, match="min_accuracy"):
        SLO(kind="accuracy")
    with pytest.raises(ValueError, match="min_accuracy"):
        SLO(kind="accuracy", min_accuracy=1.5)


def test_gear_and_plan_validation_raises_valueerror():
    reps = [Replica("a", 0, 1e-3)]
    with pytest.raises(ValueError, match="min queue"):
        Gear(cascade=Cascade(("a",), ()), min_queue_lens={"a": 0},
             load_fractions={})
    with pytest.raises(ValueError, match="load fraction"):
        Gear(cascade=Cascade(("a",), ()), min_queue_lens={"a": 1},
             load_fractions={"a": {0: -0.5}})
    g = make_gear(Cascade(("a",), ()), reps)
    with pytest.raises(ValueError, match="qps_max"):
        GearPlan(qps_max=0.0, gears=[g], replicas=reps, num_devices=1,
                 slo=SLO(kind="latency", latency_p95=1.0))
    with pytest.raises(ValueError, match="at least one gear"):
        GearPlan(qps_max=10.0, gears=[], replicas=reps, num_devices=1,
                 slo=SLO(kind="latency", latency_p95=1.0))


def test_tenant_spec_validation_and_roundtrip():
    with pytest.raises(ValueError, match="name"):
        TenantSpec("", SLO(kind="latency", latency_p95=1.0), 100.0)
    with pytest.raises(ValueError, match="qps_max"):
        TenantSpec("t", SLO(kind="latency", latency_p95=1.0), 0.0)
    with pytest.raises(ValueError, match="weight"):
        TenantSpec("t", SLO(kind="latency", latency_p95=1.0), 10.0,
                   weight=-1.0)
    with pytest.raises(ValueError, match="qps_prior"):
        TenantSpec("t", SLO(kind="latency", latency_p95=1.0), 10.0,
                   n_ranges=4, qps_prior=(0.5, 0.5))
    spec = TenantSpec("t", SLO(kind="accuracy", min_accuracy=0.8), 123.0,
                      weight=0.0, n_ranges=2, qps_prior=(0.75, 0.25))
    back = TenantSpec.from_dict(spec.to_dict())
    assert back == spec
    assert back.slo.kind == "accuracy" and back.slo.min_accuracy == 0.8


def test_multitenant_plan_roundtrip_covers_tenant_fields(mt_report):
    (report, hw) = mt_report
    mt = report.plan
    back = MultiTenantPlan.from_json(mt.to_json())
    assert back.names == mt.names
    assert back.tenants == mt.tenants        # specs incl. SLO round-trip
    assert back.gear_demand == mt.gear_demand
    for n in mt.names:
        # full nested GearPlan round trip (gears, SLO, replicas,
        # provenance) — the plan dicts must be reconstructed exactly
        assert back.plans[n].to_dict() == mt.plans[n].to_dict()
    # shared placement survives the round trip
    assert [(r.model, r.device) for r in back.replicas] == \
        [(r.model, r.device) for r in mt.replicas]


def test_multitenant_plan_rejects_split_placement(small_family):
    reps_a = [Replica("tiny", 0, 1e-3)]
    reps_b = [Replica("tiny", 1, 1e-3)]
    slo = SLO(kind="latency", latency_p95=1.0)
    mk = lambda reps: GearPlan(
        qps_max=10.0, gears=[make_gear(Cascade(("tiny",), ()), reps)],
        replicas=reps, num_devices=2, slo=slo)
    specs = [TenantSpec("a", slo, 10.0), TenantSpec("b", slo, 10.0)]
    with pytest.raises(ValueError, match="share the placement"):
        MultiTenantPlan(tenants=specs,
                        plans={"a": mk(reps_a), "b": mk(reps_b)})
    with pytest.raises(ValueError, match="duplicate"):
        MultiTenantPlan(tenants=[specs[0], specs[0]],
                        plans={"a": mk(reps_a)})


# ---------------------------------------------------------------------------
# Planner extension: joint placement + pinned per-tenant ladders
# ---------------------------------------------------------------------------

def test_joint_plan_shares_one_placement(mt_report):
    (report, hw) = mt_report
    mt = report.plan
    ref = [(r.model, r.device) for r in mt.replicas]
    for n in mt.names:
        assert [(r.model, r.device) for r in mt.plans[n].replicas] == ref
        # per-tenant provenance: each ladder watches its own assumptions
        assert mt.plans[n].provenance is not None
        assert mt.plans[n].provenance.qps_max == mt.spec(n).qps_max
    # demand coefficients: first model of each gear carries full traffic
    for n in mt.names:
        for gi, demand in enumerate(mt.gear_demand[n]):
            first = mt.plans[n].gears[gi].cascade.models[0]
            assert demand[first] == pytest.approx(1.0)
    # the pinned pass recorded a warm state per tenant (re-plan seed)
    assert set(report.reports) == set(mt.names)
    assert all(report.reports[n].state is not None for n in mt.names)


# ---------------------------------------------------------------------------
# Determinism: keyed RNG streams + tenant insertion
# ---------------------------------------------------------------------------

def test_route_pool_keyed_streams_are_independent():
    # keyed pools derive from (seed, key), not from construction order or
    # other pools' consumption
    a1 = RoutePool(7, size=64, key="alpha")
    b = RoutePool(7, size=64, key="beta")
    seq_interleaved = []
    for _ in range(32):
        seq_interleaved.append(a1.next())
        b.next()          # consuming beta must not shift alpha
    a2 = RoutePool(7, size=64, key="alpha")
    seq_solo = [a2.next() for _ in range(32)]
    assert seq_interleaved == seq_solo
    # distinct keys give distinct streams; key=None is the legacy stream
    assert RoutePool(7, size=64, key="alpha")._pool != \
        RoutePool(7, size=64, key="beta")._pool
    legacy = np.random.default_rng(7).random(64).tolist()
    assert RoutePool(7, size=64)._pool == legacy


def test_inserting_idle_tenant_leaves_decisions_unchanged(mt_report,
                                                          small_family):
    """THE tenancy determinism contract: adding a tenant (here with no
    traffic, so shared-queue physics are unchanged) must leave every other
    tenant's decision trace bit-identical — per-tenant cores, keyed route
    streams, and per-tenant measurement make the loop insertion-stable."""
    (report, hw) = mt_report
    mt = report.plan
    solo = single_tenant_plan(mt.spec("interactive"),
                              report.reports["interactive"])
    trace = np.concatenate([np.full(3, 100.0), np.full(3, 380.0),
                            np.full(3, 100.0)])
    sim = ServingSimulator(small_family, mt.replicas, hw.num_devices,
                           SimConfig(max_batch=128))

    tr1 = {"interactive": DecisionTrace()}
    r1 = sim.run_multi_tenant(solo, {"interactive": trace},
                              decision_traces=tr1)
    tr2 = {"interactive": DecisionTrace(), "analytics": DecisionTrace()}
    r2 = sim.run_multi_tenant(
        mt, {"interactive": trace, "analytics": np.zeros(9)},
        decision_traces=tr2)

    a, b = tr1["interactive"], tr2["interactive"]
    assert a.routes == b.routes
    assert a.gear_switches == b.gear_switches
    assert a.hops == b.hops
    assert r1["interactive"].result.completed == \
        r2["interactive"].result.completed
    np.testing.assert_array_equal(r1["interactive"].result.latencies,
                                  r2["interactive"].result.latencies)
    # the idle tenant exists but saw nothing
    assert r2["analytics"].offered == 0


def test_effective_trigger_ignores_absent_tenants(small_family):
    reps = [Replica("tiny", 0, 1e-3)]
    eager = make_gear(Cascade(("tiny",), ()), reps, {"tiny": 2})
    lazy = make_gear(Cascade(("tiny",), ()), reps, {"tiny": 16})
    # only tenants with queued samples count; min wins among those
    assert effective_trigger("tiny", [0, 3], [eager, lazy]) == 16
    assert effective_trigger("tiny", [1, 3], [eager, lazy]) == 2
    assert effective_trigger("tiny", [0, 0], [eager, lazy]) == 1


def test_merge_tenant_arrivals_stable_ties():
    times, tidx, lidx = merge_tenant_arrivals(
        {"a": np.array([2.0]), "b": np.array([2.0])}, ["a", "b"])
    # equal per-second rates arrive at identical offsets: tenant order
    # breaks the tie deterministically
    assert times.tolist() == [0.25, 0.25, 0.75, 0.75]
    assert tidx.tolist() == [0, 1, 0, 1]
    assert lidx.tolist() == [0, 0, 1, 1]


# ---------------------------------------------------------------------------
# Executor parity: simulator vs MultiTenantServer (virtual time)
# ---------------------------------------------------------------------------

class _ReplayEngine:
    def __init__(self, certs):
        self.certs = np.asarray(certs, np.float64)

    def infer(self, tokens):
        vi = np.asarray(tokens)[:, 0] % len(self.certs)
        out = np.zeros((len(vi), 2))
        out[:, 0] = self.certs[vi]
        return out


def _cert_estimator(scores):
    return scores[:, 0]


def test_multitenant_executors_make_identical_decisions(mt_report,
                                                        small_family):
    """The fidelity contract extended to tenancy: the DES and the real
    runtime (virtual time), fed the same superposed tenant traces and the
    same admission controller, must record element-wise identical
    per-tenant decision traces AND fleet-level batch firings."""
    from repro.core import AdmissionController
    from repro.serving.runtime import MultiTenantServer, Request

    (report, hw) = mt_report
    mt = report.plan
    profiles = small_family
    traces = {"interactive": np.concatenate([np.full(3, 100.0),
                                             np.full(3, 900.0),
                                             np.full(3, 100.0)]),
              "analytics": np.full(9, 150.0)}

    tr_sim = {n: DecisionTrace() for n in mt.names}
    fleet_sim = DecisionTrace()
    sim = ServingSimulator(profiles, mt.replicas, hw.num_devices,
                           SimConfig(max_batch=128))
    out = sim.run_multi_tenant(mt, traces,
                               admission=AdmissionController(mt),
                               decision_traces=tr_sim,
                               fleet_trace=fleet_sim)

    times, tidx, lidx = merge_tenant_arrivals(traces, mt.names)
    reqs = {n: [None] * int((tidx == i).sum())
            for i, n in enumerate(mt.names)}
    for g in range(len(times)):
        n = mt.names[int(tidx[g])]
        reqs[n][int(lidx[g])] = Request(
            rid=g, tokens=np.array([int(lidx[g])], np.int64))
    pools = {n: RoutePool.for_arrivals(0, len(reqs[n]), key=n)
             for n in mt.names}
    tr_srv = {n: DecisionTrace() for n in mt.names}
    fleet_srv = DecisionTrace()
    engines = {m: _ReplayEngine(profiles[m].validation.certs)
               for m in profiles}
    srv = MultiTenantServer(mt, engines, estimator=_cert_estimator,
                            max_batch=128,
                            admission=AdmissionController(mt),
                            decision_traces=tr_srv, fleet_trace=fleet_srv,
                            route_pools=pools)
    done = srv.run_virtual(reqs, traces,
                           batch_runtime=lambda m, b: profiles[m].runtime(b))

    # the scenario exercises every decision type in both tenants
    assert len(tr_sim["interactive"].gear_switches) >= 2
    assert len(fleet_sim.fires) > 10
    assert any(h[2] != "resolve" for h in tr_sim["interactive"].hops)

    for n in mt.names:
        assert tr_sim[n].routes == tr_srv[n].routes
        assert tr_sim[n].gear_switches == tr_srv[n].gear_switches
        assert tr_sim[n].hops == tr_srv[n].hops
    assert fleet_sim.fires == fleet_srv.fires
    for n in mt.names:
        assert out[n].result.completed == len(done[n])
        assert out[n].shed == srv.shed_counts[n]


# ---------------------------------------------------------------------------
# Per-tenant re-planning: only the drifted tenant's ladder moves
# ---------------------------------------------------------------------------

def test_only_drifted_tenant_replans(mt_report, small_family):
    from repro.core import MonitorConfig

    (report, hw) = mt_report
    mt = report.plan
    lcs = make_tenant_lifecycles(
        report, small_family, hw,
        monitor_cfg=MonitorConfig(qps_sustain_ticks=3, cooldown=60.0),
        plan_latency=0.5)
    sim = ServingSimulator(small_family, mt.replicas, hw.num_devices,
                           SimConfig())
    # interactive rides to 2x its qps_max; analytics stays in range
    traces = {"interactive": np.concatenate([np.full(2, 300.0),
                                             np.full(6, 800.0),
                                             np.full(4, 300.0)]),
              "analytics": np.full(12, 100.0)}
    out = sim.run_multi_tenant(mt, traces, lifecycles=lcs)

    drifted, steady = lcs["interactive"], lcs["analytics"]
    assert len(drifted.swaps) >= 1
    assert drifted.swaps[0].reason == "qps-exceeds-range"
    assert drifted.active.plan.qps_max > mt.spec("interactive").qps_max
    # the placement stayed pinned through the tenant re-plan
    assert [(r.model, r.device) for r in drifted.active.plan.replicas] == \
        [(r.model, r.device) for r in mt.replicas]
    # the steady tenant's plan is untouched (no swap, same object)
    assert not steady.swaps
    assert steady.active.plan is mt.plans["analytics"]
    assert out["interactive"].result.plan_swaps
    assert not out["analytics"].result.plan_swaps


# ---------------------------------------------------------------------------
# Static partitioning control
# ---------------------------------------------------------------------------

def test_partition_devices_weight_proportional():
    from repro.serving.baselines import partition_devices
    slo = SLO(kind="latency", latency_p95=1.0)
    ts = [TenantSpec("a", slo, 10.0, weight=3.0),
          TenantSpec("b", slo, 10.0, weight=1.0)]
    assert partition_devices(ts, 4) == {"a": 3, "b": 1}
    # minimum one device each, even at weight 0
    ts0 = [TenantSpec("a", slo, 10.0, weight=1.0),
           TenantSpec("b", slo, 10.0, weight=0.0)]
    assert partition_devices(ts0, 2) == {"a": 1, "b": 1}
    with pytest.raises(ValueError, match="partition"):
        partition_devices(ts, 1)


def test_static_partition_builds_independent_plans(small_family,
                                                   two_tenants):
    from repro.serving.baselines import StaticPartitionPolicy
    hw = HardwareSpec(num_devices=2, mem_per_device=16e9)
    built = StaticPartitionPolicy().build_plans(small_family, hw,
                                                two_tenants)
    assert set(built) == {"interactive", "analytics"}
    total = 0
    for n, (mt1, hw_t, rep) in built.items():
        assert mt1.names == [n]
        assert mt1.num_devices == hw_t.num_devices
        total += hw_t.num_devices
        # each partition plan is servable on its own slice
        assert all(r.device < hw_t.num_devices for r in mt1.replicas)
    assert total == hw.num_devices
