"""Discrete-event simulator invariants + fault-tolerance machinery."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.cascade import Cascade
from repro.core.lp import Replica
from repro.core.simulator import (ServingSimulator, SimConfig, make_gear,
                                  trace_to_arrivals)
from repro.distributed.fault_tolerance import HedgePolicy


def _sim(profiles, n_dev=2):
    reps = []
    for d in range(n_dev):
        for m in profiles:
            reps.append(Replica(m, d, profiles[m].runtime_per_sample(1.0)))
    return ServingSimulator(profiles, reps, n_dev), reps


def test_stable_at_low_qps(bert_like_profiles):
    sim, reps = _sim(bert_like_profiles)
    g = make_gear(Cascade(("tiny", "base"), (0.3,)), reps)
    res = sim.run_fixed(g, qps=100, horizon=3.0)
    assert res.stable
    assert res.completed == res.offered
    assert res.p95 < 0.2


def test_unstable_when_overloaded(bert_like_profiles):
    sim, reps = _sim(bert_like_profiles)
    g = make_gear(Cascade(("base",), ()), reps)  # ~6.6ms/sample, 2 devices
    res = sim.run_fixed(g, qps=5000, horizon=2.0)
    assert not res.stable


def test_latency_at_least_service_time(bert_like_profiles):
    sim, reps = _sim(bert_like_profiles)
    g = make_gear(Cascade(("tiny",), ()), reps)
    res = sim.run_fixed(g, qps=50, horizon=2.0)
    min_rt = bert_like_profiles["tiny"].runtime(1)
    assert res.latencies.min() >= min_rt - 1e-9


def test_batching_tradeoff(bert_like_profiles):
    """Bigger min-queue trigger -> higher throughput ceiling, more waiting
    at low load (the paper's §4.5 trade-off)."""
    sim, reps = _sim(bert_like_profiles)
    g1 = make_gear(Cascade(("base",), ()), reps, {"base": 1})
    g8 = make_gear(Cascade(("base",), ()), reps, {"base": 16})
    lo1 = sim.run_fixed(g1, qps=40, horizon=3.0)
    lo8 = sim.run_fixed(g8, qps=40, horizon=3.0)
    assert lo8.latencies.mean() > lo1.latencies.mean()
    hi1 = sim.run_fixed(g1, qps=1200, horizon=3.0)
    hi8 = sim.run_fixed(g8, qps=1200, horizon=3.0)
    assert hi8.p95 <= hi1.p95 * 1.05 or (hi8.stable and not hi1.stable)


def test_accuracy_matches_eval(bert_like_profiles):
    from repro.core.cascade import evaluate_cascade
    sim, reps = _sim(bert_like_profiles)
    c = Cascade(("tiny", "base"), (0.35,))
    g = make_gear(c, reps)
    res = sim.run_fixed(g, qps=500, horizon=4.0)
    ev = evaluate_cascade(c, bert_like_profiles)
    assert res.accuracy == pytest.approx(ev.accuracy, abs=0.01)
    frac_forwarded = res.per_model_samples.get("base", 0) / res.offered
    assert frac_forwarded == pytest.approx(ev.fractions[1], abs=0.02)


def test_ensemble_mode(bert_like_profiles):
    sim, reps = _sim(bert_like_profiles, n_dev=3)
    g = make_gear(Cascade(("tiny", "small", "base"), (0.0, 0.0)), reps,
                  mode="ensemble")
    res = sim.run_fixed(g, qps=100, horizon=2.0)
    # the final arrival's members may straddle the horizon (no drain here)
    assert res.completed >= res.offered - 3
    votes = np.stack([bert_like_profiles[m].validation.correct
                      for m in ("tiny", "small", "base")])
    maj = (votes.sum(0) * 2 > 3)
    assert res.accuracy == pytest.approx(maj.mean(), abs=0.02)


def test_trace_to_arrivals():
    arr = trace_to_arrivals(np.array([2.0, 0.0, 3.0]))
    assert len(arr) == 5
    assert (arr[:2] < 1).all() and (arr[2:] >= 2).all()
    assert (np.diff(arr) >= 0).all()


def test_device_failure_and_rebalance(bert_like_profiles, small_plan):
    from repro.distributed.fault_tolerance import rebalance_on_failure
    report, hw = small_plan
    plan = report.plan
    sim = ServingSimulator(bert_like_profiles, plan.replicas, hw.num_devices)
    # high enough load that the LP spreads work over every device
    trace = np.full(20, 4000.0)
    events = [(5.0, 0, "fail", 0.0)]
    r_no = sim.run_trace(plan, trace, device_events=events)

    def on_fail(t, dev):
        return rebalance_on_failure(plan, bert_like_profiles, {dev}).gears
    r_fix = sim.run_trace(plan, trace, device_events=events,
                          on_failure=on_fail)
    # rebalancing strictly improves completion (or both complete fully and
    # rebalancing improves tail latency)
    if r_no.completed < r_no.offered:
        assert r_fix.completed > r_no.completed
    else:
        assert r_fix.latency_quantile(0.99) <= \
            r_no.latency_quantile(0.99) * 1.5
    assert r_fix.completed >= 0.99 * r_fix.offered


def test_straggler_hedging(bert_like_profiles, small_plan):
    report, hw = small_plan
    plan = report.plan
    sim = ServingSimulator(bert_like_profiles, plan.replicas, hw.num_devices)
    trace = np.full(30, 500.0)
    events = [(5.0, 1, "slow", 10.0), (20.0, 1, "recover", 1.0)]
    r_plain = sim.run_trace(plan, trace, device_events=events)
    r_hedge = sim.run_trace(plan, trace, device_events=events,
                            hedge=HedgePolicy(hedge_multiplier=2.0))
    assert r_hedge.completed >= r_plain.completed
    assert r_hedge.latency_quantile(0.99) <= \
        r_plain.latency_quantile(0.99) * 1.05


@given(st.integers(0, 10 ** 6))
@settings(max_examples=10, deadline=None)
def test_conservation_property(seed):
    """completed + backlog == offered, and latencies are positive."""
    from repro.core.profiles import synthetic_family
    rng = np.random.default_rng(seed)
    profiles = synthetic_family(["a", "b"], seed=seed % 997, n_val=256,
                                base_runtime=float(rng.uniform(1e-4, 2e-3)))
    reps = [Replica(m, d, profiles[m].runtime_per_sample(1.0))
            for m in profiles for d in range(2)]
    sim = ServingSimulator(profiles, reps, 2)
    g = make_gear(Cascade(("a", "b"), (float(rng.uniform(0, 0.6)),)), reps,
                  {"a": int(rng.integers(1, 8))})
    res = sim.run_fixed(g, qps=float(rng.uniform(20, 800)), horizon=2.0)
    assert res.completed + res.backlog_end == res.offered
    if res.completed:
        assert (res.latencies > 0).all()
        assert res.accuracy >= 0.3  # sanity: better than random-ish
