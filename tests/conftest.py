"""Shared fixtures. NOTE: no XLA device-count flags here — smoke tests and
benches must see the real (single) device; multi-device tests run in
subprocesses that set XLA_FLAGS before importing jax."""
import os
import sys

import numpy as np
import pytest

# The five property-test modules guard with importorskip("hypothesis").
# Where the real package is unavailable (hermetic CI container), expose the
# vendored minimal stub in tests/_compat so the properties still EXECUTE
# instead of silently skipping; a real installation always wins.
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_compat"))


@pytest.fixture(scope="session")
def bert_like_profiles():
    from repro.core.profiles import synthetic_family
    return synthetic_family(
        ["tiny", "mini", "small", "medium", "base"],
        base_runtime=2e-4, runtime_ratio=2.4, base_acc=0.70,
        acc_gain=0.05, mem_base=0.4e9, seed=3)


@pytest.fixture(scope="session")
def llama_like_profiles():
    from repro.core.profiles import synthetic_family
    return synthetic_family(
        ["l3b", "l7b", "l13b", "l70b"],
        base_runtime=3e-2, runtime_ratio=2.2, base_acc=0.45,
        acc_gain=0.05, mem_base=2e9, seed=4)


@pytest.fixture(scope="session")
def small_plan(bert_like_profiles):
    from repro.core import HardwareSpec, SLO, optimize_gear_plan
    hw = HardwareSpec(num_devices=4, mem_per_device=16e9)
    slo = SLO(kind="latency", latency_p95=0.4)
    return optimize_gear_plan(bert_like_profiles, hw, slo,
                              qps_max=7600, n_ranges=8), hw
