"""Simplex LP + load-balancing LP (Eq. 1-3), incl. hypothesis feasibility
properties and the bisection <-> direct-LP cross-check."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.lp import (Replica, linprog, min_utilization,
                           min_utilization_lp, solve_load_balance)


def test_linprog_known_solution():
    res = linprog(np.array([-1.0, -1.0]),
                  np.array([[1.0, 0.0], [0.0, 1.0], [1.0, 1.0]]),
                  np.array([2.0, 3.0, 4.0]))
    assert res.status == "optimal"
    assert res.objective == pytest.approx(-4.0)


def test_linprog_infeasible():
    res = linprog(np.array([1.0]), np.array([[1.0], [-1.0]]),
                  np.array([-5.0, 3.0]))  # x <= -5 and x >= -3
    assert res.status == "infeasible"


def test_linprog_geq_via_negation():
    res = linprog(np.array([1.0]), np.array([[-1.0]]), np.array([-3.0]))
    assert res.status == "optimal"
    assert res.x[0] == pytest.approx(3.0)


@given(st.integers(0, 10 ** 6))
@settings(max_examples=30, deadline=None)
def test_linprog_feasibility_property(seed):
    """On random feasible instances, the solution satisfies constraints."""
    rng = np.random.default_rng(seed)
    n, m = rng.integers(2, 6), rng.integers(2, 6)
    a = rng.uniform(-1, 1, (m, n))
    x0 = rng.uniform(0, 2, n)           # known feasible point
    b = a @ x0 + rng.uniform(0.1, 1.0, m)
    c = rng.uniform(-1, 1, n)
    res = linprog(c, a, b)
    if res.status == "optimal":
        assert np.all(a @ res.x <= b + 1e-6)
        assert np.all(res.x >= -1e-9)
        assert c @ res.x <= c @ x0 + 1e-6  # at least as good as x0
    else:
        assert res.status == "unbounded"  # possible with negative costs


def _mk_replicas():
    return [Replica("a", 0, 0.001), Replica("a", 1, 0.001),
            Replica("b", 0, 0.010), Replica("b", 1, 0.010)]


def test_load_balance_meets_demand():
    q = solve_load_balance(_mk_replicas(), {"a": 500.0, "b": 60.0}, 2, 1.0)
    assert q is not None
    assert q[0] + q[1] >= 500.0 - 1e-6
    assert q[2] + q[3] >= 60.0 - 1e-6


def test_load_balance_infeasible_when_overloaded():
    q = solve_load_balance(_mk_replicas(), {"a": 500.0, "b": 200.0}, 2, 1.0)
    assert q is None  # 0.5 + 2.0 device-seconds > 2 devices


def test_missing_model_infeasible():
    q = solve_load_balance([Replica("a", 0, 0.001)], {"b": 1.0}, 1, 1.0)
    assert q is None


def test_min_utilization_known_value():
    u, q = min_utilization(_mk_replicas(), {"a": 500.0, "b": 60.0}, 2)
    # total work = 0.5 + 0.6 = 1.1 device-seconds over 2 devices
    assert u == pytest.approx(0.55, abs=0.01)


@given(st.integers(0, 10 ** 6))
@settings(max_examples=15, deadline=None)
def test_direct_lp_matches_bisection(seed):
    """min_utilization_lp (1 LP) == the paper's bisection (within tol)."""
    rng = np.random.default_rng(seed)
    n_dev = int(rng.integers(2, 5))
    models = ["m0", "m1", "m2"][:rng.integers(2, 4)]
    reps = []
    for m_i, m in enumerate(models):
        for d in range(n_dev):
            if rng.random() < 0.75:
                reps.append(Replica(m, d, float(rng.uniform(1e-4, 5e-3))))
    demand = {m: float(rng.uniform(10, 300)) for m in models}
    u_bis, _ = min_utilization(reps, demand, n_dev, tol=1e-4)
    u_lp, _ = min_utilization_lp(reps, demand, n_dev)
    if u_bis is None or u_lp is None:
        assert u_bis is None and u_lp is None
    else:
        assert u_lp == pytest.approx(u_bis, abs=5e-3)


# ---------------------------------------------------------------------------
# Simplex edge cases (previously only exercised indirectly through SP3)
# ---------------------------------------------------------------------------

def test_linprog_unbounded():
    # min -x with only x <= inf-style slack: objective decreases forever
    res = linprog(np.array([-1.0]), np.array([[-1.0]]), np.array([0.0]))
    assert res.status == "unbounded"
    assert res.x is None


def test_linprog_unbounded_direction_in_subspace():
    # x0 bounded, but x1 unbounded below the objective
    res = linprog(np.array([0.0, -1.0]),
                  np.array([[1.0, 0.0]]), np.array([5.0]))
    assert res.status == "unbounded"


def test_linprog_degenerate_redundant_constraints():
    # the same constraint three times (degenerate basis; Bland's rule must
    # not cycle) plus a binding one
    res = linprog(np.array([-1.0, -1.0]),
                  np.array([[1.0, 1.0], [1.0, 1.0], [1.0, 1.0],
                            [1.0, 0.0]]),
                  np.array([2.0, 2.0, 2.0, 1.0]))
    assert res.status == "optimal"
    assert res.objective == pytest.approx(-2.0)
    assert np.all(res.x >= -1e-9)


def test_linprog_degenerate_zero_rhs():
    # b = 0 rows force a degenerate vertex at the origin
    res = linprog(np.array([1.0, 1.0]),
                  np.array([[1.0, -1.0], [-1.0, 1.0]]),
                  np.array([0.0, 0.0]))
    assert res.status == "optimal"
    assert res.objective == pytest.approx(0.0)


def test_linprog_infeasible_three_way():
    # x + y <= 1, x >= 2 (via negation), y >= 2: jointly impossible
    res = linprog(np.array([1.0, 1.0]),
                  np.array([[1.0, 1.0], [-1.0, 0.0], [0.0, -1.0]]),
                  np.array([1.0, -2.0, -2.0]))
    assert res.status == "infeasible"
    assert res.x is None


def test_linprog_tight_equality_like_pair():
    # x <= 3 and x >= 3 pin x exactly; objective must honour it
    res = linprog(np.array([1.0]),
                  np.array([[1.0], [-1.0]]), np.array([3.0, -3.0]))
    assert res.status == "optimal"
    assert res.x[0] == pytest.approx(3.0)


def test_min_utilization_lp_zero_demand():
    u, q = min_utilization_lp(_mk_replicas(), {"a": 0.0, "b": 0.0}, 2)
    assert u == pytest.approx(0.0, abs=1e-6)
    assert np.all(q <= 1e-6)
