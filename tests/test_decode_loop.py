"""Device-resident decode loop (DESIGN.md §14): fused-step decision parity
vs the reference host loop, speculative multi-token scans (K-collapse rule,
trace replay, discard), bucketed batched prefill exactness + gating, the
device-side certainty fold vs the host fold, engine-vs-token-DES decision
parity through recorded gap streams, and compile-count stability."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.cascade import Cascade
from repro.core.certainty import (StreamingCertainty, device_fold_init,
                                  device_fold_update, device_fold_value)
from repro.core.execution import TokenReplayBackend
from repro.core.gears import Gear
from repro.core.lp import Replica
from repro.core.profiles import synthetic_family
from repro.core.scheduling import (CascadeHop, ContinuousBatcher,
                                   SchedulerConfig, SchedulerCore)
from repro.core.simulator import ServingSimulator, SimConfig
from repro.models import model as M
from repro.serving.token_engine import (SlotEngine, TokenEngine,
                                        TokenRequest, greedy_generate)


def _setup(arch, seed=0):
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    return cfg, params


def _requests(cfg, n, rng, base=8, max_new=6):
    return [TokenRequest(i, rng.integers(0, cfg.vocab_size,
                                         base + 3 * i).astype(np.int32),
                         max_new) for i in range(n)]


def _gear1():
    return Gear(cascade=Cascade(("m",), ()), min_queue_lens={"m": 1},
                load_fractions={"m": {0: 1.0}})


# ---------------------------------------------------------------------------
# Fused loop vs reference loop: bit-identical decisions at K=1
# ---------------------------------------------------------------------------

def test_fused_matches_reference_bit_identical():
    """The device-resident loop must be invisible: same tokens, same
    decisions, same logical timings as the PR-7 host loop at K=1."""
    cfg, params = _setup("qwen2-0.5b", seed=0)
    rng = np.random.default_rng(0)
    reqs = _requests(cfg, 5, rng)
    outs = {}
    for mode in ("fused", "reference"):
        eng = SlotEngine("m", params, cfg, n_slots=3, max_len=40)
        te = TokenEngine([eng], _gear1(), min_tokens=2, mode=mode)
        outs[mode] = te.serve(reqs)
    for r in reqs:
        f, g = outs["fused"][r.rid], outs["reference"][r.rid]
        assert f.tokens == g.tokens
        assert f.resolver == g.resolver and f.hops == g.hops
        assert f.first_token_step == g.first_token_step
        assert f.done_step == g.done_step
        np.testing.assert_allclose(f.gaps, g.gaps, atol=1e-4, rtol=0)


def test_fused_escalation_matches_reference():
    cfg, params_a = _setup("qwen2-0.5b", seed=0)
    _, params_b = _setup("qwen2-0.5b", seed=7)
    rng = np.random.default_rng(2)
    gear = Gear(cascade=Cascade(("a", "b"), (1e9,)),
                min_queue_lens={"a": 1, "b": 1},
                load_fractions={"a": {0: 1.0}, "b": {1: 1.0}})
    reqs = _requests(cfg, 3, rng, max_new=6)
    outs = {}
    for mode in ("fused", "reference"):
        stages = [SlotEngine("a", params_a, cfg, n_slots=2, max_len=40),
                  SlotEngine("b", params_b, cfg, n_slots=2, max_len=40)]
        te = TokenEngine(stages, gear, min_tokens=2, mode=mode)
        outs[mode] = te.serve(reqs)
    for rid in outs["fused"]:
        f, g = outs["fused"][rid], outs["reference"][rid]
        assert f.tokens == g.tokens and f.resolver == g.resolver == 1
        assert f.hops == g.hops >= 1
        assert sorted(f.stage_gaps) == sorted(g.stage_gaps)
        for si in f.stage_gaps:
            np.testing.assert_allclose(f.stage_gaps[si], g.stage_gaps[si],
                                       atol=1e-4, rtol=0)


# ---------------------------------------------------------------------------
# Speculative multi-token scans
# ---------------------------------------------------------------------------

def test_spec_k_decisions_match_single_step():
    """K>1 scans change WHEN work executes, never WHAT is decided: same
    tokens, resolver, hops and per-stage gap streams as K=1."""
    cfg, params = _setup("qwen2-0.5b", seed=0)
    rng = np.random.default_rng(1)
    reqs = _requests(cfg, 4, rng, max_new=8)
    outs = {}
    for k in (1, 4):
        eng = SlotEngine("m", params, cfg, n_slots=4, max_len=48)
        te = TokenEngine([eng], _gear1(), min_tokens=2, spec_k=k)
        outs[k] = (te.serve(reqs), te, eng)
    out1, _, _ = outs[1]
    out4, te4, eng4 = outs[4]
    for r in reqs:
        assert out4[r.rid].tokens == out1[r.rid].tokens
        assert out4[r.rid].resolver == out1[r.rid].resolver
        assert out4[r.rid].hops == out1[r.rid].hops
        assert out4[r.rid].stage_gaps.keys() == out1[r.rid].stage_gaps.keys()
    # a terminal-stage stream is never near a boundary, so once everyone
    # is resident the scans actually batch steps: fewer executable calls
    # than decode steps executed
    assert eng4.stats.decode_calls < eng4.stats.decode_steps
    assert te4.spec_discarded == 0       # single stage: nothing discarded


def test_stream_trace_hop_consumes_to_first_decision():
    """The trace replay stops at the first boundary decision; tokens past
    it are speculative and reported as unconsumed."""
    core = SchedulerCore([Replica("a", 0, 1e-3), Replica("b", 1, 2e-3)],
                         SchedulerConfig())
    gear = Gear(cascade=Cascade(("a", "b"), (0.6,)),
                min_queue_lens={"a": 1, "b": 1},
                load_fractions={"a": {0: 1.0}, "b": {1: 1.0}})
    cb = ContinuousBatcher(core, n_slots=4, min_tokens=2, early_margin=0.5)
    cert = StreamingCertainty(mode="min")
    cert.update(0.9)                     # prefill token: confident
    # trace collapses at its 3rd token (min fold -> 0.1 < 0.6 * 0.5)
    used, hop = cb.stream_trace_hop(0, cert, [0.8, 0.7, 0.1, 0.9], 1, 10,
                                    gear)
    assert used == 3 and isinstance(hop, CascadeHop)
    assert cert.count == 4               # unconsumed gap was never folded
    # a confident trace consumes everything and keeps decoding
    cert2 = StreamingCertainty(mode="min")
    cert2.update(0.9)
    used2, hop2 = cb.stream_trace_hop(0, cert2, [0.9, 0.9], 1, 10, gear)
    assert used2 == 2 and hop2 is None


def test_near_boundary_guard_and_validation():
    core = SchedulerCore([Replica("a", 0, 1e-3), Replica("b", 1, 2e-3)],
                         SchedulerConfig())
    gear = Gear(cascade=Cascade(("a", "b"), (0.6,)),
                min_queue_lens={"a": 1, "b": 1},
                load_fractions={"a": {0: 1.0}, "b": {1: 1.0}})
    cb = ContinuousBatcher(core, n_slots=4, min_tokens=2, early_margin=0.5)
    # escalation band is cert < 0.3; slack 1.5 widens nearness to 0.45
    assert cb.near_boundary(0, 0.40, 5, 10, gear, slack=1.5)
    assert not cb.near_boundary(0, 0.50, 5, 10, gear, slack=1.5)
    assert not cb.near_boundary(1, 0.0, 5, 10, gear)   # terminal stage
    cfg, params = _setup("qwen2-0.5b", seed=0)
    eng = SlotEngine("m", params, cfg, n_slots=2, max_len=16)
    with pytest.raises(ValueError):
        TokenEngine([eng], _gear1(), mode="reference", spec_k=2)
    with pytest.raises(ValueError):
        TokenEngine([eng], _gear1(), mode="turbo")
    with pytest.raises(ValueError):
        TokenEngine([eng], _gear1(), spec_k=0)
    with pytest.raises(RuntimeError):
        eng.decode_fused()               # nothing resident
    eng.prefill_batch([np.arange(4, dtype=np.int32)])
    with pytest.raises(ValueError):
        eng.decode_fused(k=0)
    with pytest.raises(ValueError):
        eng.decode_fused(k=13)           # 4 + 13 > max_len: scan overrun


# ---------------------------------------------------------------------------
# Device-side certainty fold vs the host fold
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["ewma", "mean", "min"])
def test_device_fold_matches_host_fold(mode):
    rng = np.random.default_rng(0)
    gaps = rng.uniform(0.0, 8.0, size=(12, 3)).astype(np.float32)
    st = device_fold_init(3)
    host = [StreamingCertainty(mode=mode, beta=0.35) for _ in range(3)]
    assert np.all(np.asarray(device_fold_value(st, mode)) == 0.0)
    for t in range(12):
        st = device_fold_update(st, jnp.asarray(gaps[t]), 0.35)
        for b in range(3):
            host[b].update(float(gaps[t, b]))
        np.testing.assert_allclose(
            np.asarray(device_fold_value(st, mode)),
            [h.value for h in host], rtol=1e-5, atol=1e-6)
    with pytest.raises(ValueError):
        device_fold_value(st, "median")


# ---------------------------------------------------------------------------
# Bucketed batched prefill: exactness and gating
# ---------------------------------------------------------------------------

def test_prefill_bucketed_matches_per_prompt():
    """Right-padded batched prefill returns each row's true-last-position
    logits — same greedy token as an exact-length batch-1 prefill."""
    cfg, params = _setup("qwen2-0.5b", seed=3)
    rng = np.random.default_rng(4)
    lens = [5, 9, 14]
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in lens]
    Lb = 16
    arr = np.zeros((4, Lb), np.int32)    # batch-bucket pad row rides along
    tl = np.ones(4, np.int32)
    for i, p in enumerate(prompts):
        arr[i, :p.size] = p
        tl[i] = p.size
    logits_b, _ = M.prefill_bucketed(params, cfg, jnp.asarray(arr),
                                     jnp.asarray(tl), cache_len=32)
    for i, p in enumerate(prompts):
        solo, _ = M.prefill(params, cfg, {"tokens": jnp.asarray(p[None])},
                            cache_len=32)
        np.testing.assert_allclose(np.asarray(logits_b[i]),
                                   np.asarray(solo[0]), atol=1e-4, rtol=0)
        assert int(np.argmax(np.asarray(logits_b[i]))) == \
            int(np.argmax(np.asarray(solo[0])))


def test_bucketed_prefill_gating():
    """Padding is only exact for attention-only decoders: SSM state and
    MoE routing configs must refuse and fall back."""
    mamba = get_smoke_config("falcon-mamba-7b")
    assert not M.bucketed_prefill_supported(mamba)
    qwen = get_smoke_config("qwen2-0.5b")
    assert M.bucketed_prefill_supported(qwen)
    params = M.init_params(mamba, jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        M.prefill_bucketed(params, mamba, jnp.zeros((2, 8), jnp.int32),
                           jnp.asarray([4, 8], jnp.int32), cache_len=16)
    qp = M.init_params(qwen, jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        M.prefill_bucketed(qp, qwen, jnp.zeros((2, 8), jnp.int32),
                           jnp.asarray([4, 8], jnp.int32), cache_len=4)


def test_fused_engine_on_ssm_falls_back_to_exact_prefill():
    """The fused loop still serves SSM cascades bit-identically — joins
    just use exact-length prefills (no padded batching)."""
    cfg, params = _setup("falcon-mamba-7b", seed=1)
    rng = np.random.default_rng(5)
    eng = SlotEngine("m", params, cfg, n_slots=2, max_len=32)
    te = TokenEngine([eng], _gear1(), min_tokens=2)
    reqs = _requests(cfg, 3, rng, base=6, max_new=4)
    out = te.serve(reqs)
    for r in reqs:
        solo, _ = greedy_generate(params, cfg, r.prompt, r.max_new)
        assert out[r.rid].tokens == solo.tolist()
    # every prefill went through the exact-length batch-1 path
    assert all(b == 1 for b, _ in eng.stats.prefill_shapes)


# ---------------------------------------------------------------------------
# Engine vs token DES: decision parity through recorded gap streams
# ---------------------------------------------------------------------------

def test_engine_vs_token_des_decision_parity():
    """Replaying the engine's recorded gap streams through the token DES
    reproduces its resolver/hop decisions exactly — the engine and the
    DES share one decision layer (DESIGN.md §13/§14)."""
    cfg, params_a = _setup("qwen2-0.5b", seed=0)
    _, params_b = _setup("qwen2-0.5b", seed=7)
    rng = np.random.default_rng(6)
    reqs = _requests(cfg, 5, rng, max_new=6)
    # pick a threshold that splits the population: median of the solo
    # end-of-stream certainty folds
    finals = []
    for r in reqs:
        _, gaps = greedy_generate(params_a, cfg, r.prompt, r.max_new)
        c = StreamingCertainty()
        for g in gaps:
            c.update(float(g))
        finals.append(c.value)
    thr = float(np.median(finals))
    gear = Gear(cascade=Cascade(("a", "b"), (thr,)),
                min_queue_lens={"a": 1, "b": 1},
                load_fractions={"a": {0: 1.0}, "b": {1: 1.0}},
                decode_slots={"a": 3, "b": 3})
    for spec_k in (1, 3):
        stages = [SlotEngine("a", params_a, cfg, n_slots=3, max_len=40),
                  SlotEngine("b", params_b, cfg, n_slots=3, max_len=40)]
        te = TokenEngine(stages, gear, min_tokens=2, spec_k=spec_k)
        out = te.serve(reqs)
        resolvers = [out[r.rid].resolver for r in reqs]
        assert 0 in resolvers and 1 in resolvers    # threshold splits
        backend = TokenReplayBackend.from_gap_streams(
            ["a", "b"], [out[r.rid].stage_gaps for r in reqs],
            [r.max_new for r in reqs])
        sim = ServingSimulator(
            synthetic_family(["a", "b"], seed=0),
            [Replica("a", 0, 1e-3), Replica("b", 1, 2e-3)], 2,
            SimConfig(max_batch=8))
        res = sim.run_token_trace(
            gear, np.zeros(len(reqs)), [r.prompt.size for r in reqs],
            backend, mode="continuous", n_slots=3, min_tokens=2)
        assert res.completed == len(reqs)
        np.testing.assert_array_equal(res.resolver, resolvers)
        np.testing.assert_array_equal(
            res.tokens_out, [len(out[r.rid].tokens) for r in reqs])
        # the busy-time breakdown covers both phases and adds up
        assert set(res.per_model_prefill_time) <= {"a", "b"}
        total = sum(res.per_model_prefill_time.values()) + \
            sum(res.per_model_decode_time.values())
        assert total == pytest.approx(float(res.device_busy.sum()))


def test_from_gap_streams_validation():
    with pytest.raises(ValueError):
        TokenReplayBackend.from_gap_streams(["a"], [], [])
    with pytest.raises(ValueError):
        TokenReplayBackend.from_gap_streams(["a"], [{0: [1.0]}], [1, 2])


# ---------------------------------------------------------------------------
# Compile stability
# ---------------------------------------------------------------------------

def test_compile_counts_bounded_by_bucket_grid():
    """The fused engine's executable count is bounded by the bucket grid
    regardless of the prompt-length distribution; the reference engine
    compiles one prefill per DISTINCT length."""
    cfg, params = _setup("qwen2-0.5b", seed=0)
    rng = np.random.default_rng(7)
    lens = [5, 6, 7, 9, 11, 13, 17, 19]          # 8 distinct lengths
    reqs = [TokenRequest(i, rng.integers(0, cfg.vocab_size,
                                         n).astype(np.int32), 4)
            for i, n in enumerate(lens)]
    eng = SlotEngine("m", params, cfg, n_slots=4, max_len=40)
    te = TokenEngine([eng], _gear1(), min_tokens=2)
    te.serve(reqs)
    cc = eng.compile_counts()
    grid = len(eng.len_buckets) * len(eng.batch_buckets)
    assert cc["bucketed_prefill"] == len(eng.stats.prefill_shapes) <= grid
    assert cc["bucketed_prefill"] < len(set(lens))   # beats per-length
    assert cc["fused_decode"] == 1                   # K=1 only
    assert cc["reference_prefill"] == cc["reference_decode"] == 0
    # the reference engine's compile count tracks the length distribution
    ref = SlotEngine("m", params, cfg, n_slots=4, max_len=40)
    tr = TokenEngine([ref], _gear1(), min_tokens=2, mode="reference")
    tr.serve(reqs)
    assert ref.compile_counts()["reference_prefill"] == len(set(lens))


def test_fused_step_transfer_is_o_b():
    """Per decode step the fused loop ships O(B) scalars, the reference
    loop O(B·V) logits — the tentpole's transfer claim, measured."""
    cfg, params = _setup("qwen2-0.5b", seed=0)
    rng = np.random.default_rng(8)
    reqs = _requests(cfg, 3, rng, max_new=5)
    per_step = {}
    for mode in ("fused", "reference"):
        eng = SlotEngine("m", params, cfg, n_slots=3, max_len=40)
        te = TokenEngine([eng], _gear1(), min_tokens=2, mode=mode)
        te.serve(reqs)
        # prefill transfers excluded: count decode-step output bytes only
        n_steps = eng.stats.decode_steps
        if mode == "fused":
            per_step[mode] = 12 * eng.n_slots
            assert eng.stats.bytes_to_host >= n_steps * per_step[mode]
        else:
            per_step[mode] = 4 * eng.n_slots * cfg.vocab_size
    assert per_step["reference"] / per_step["fused"] == \
        pytest.approx(cfg.vocab_size / 3.0)
