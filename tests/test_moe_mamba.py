"""MoE dispatch + Mamba scan unit tests against dense/sequential oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke_config
from repro.models import mamba as mamba_lib
from repro.models import moe as moe_lib
from repro.models.common import ArrayFactory


def _moe_setup(dtype=jnp.float32):
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    f = ArrayFactory(jax.random.PRNGKey(0), False, dtype)
    return cfg, moe_lib.make_moe_params(f, cfg)


def _moe_oracle(p, cfg, x):
    m = cfg.moe
    e_pad = p["router"].shape[-1]
    logits = x @ p["router"]
    logits = jnp.where(jnp.arange(e_pad) < m.num_experts, logits, -1e30)
    if m.norm_topk_prob:
        probs = jax.nn.softmax(logits, -1)
        w, idx = jax.lax.top_k(probs, m.top_k)
        w = w / w.sum(-1, keepdims=True)
    else:
        tl, idx = jax.lax.top_k(logits, m.top_k)
        w = jax.nn.sigmoid(tl)
    outs = []
    for e in range(m.num_experts):
        h = jax.nn.silu(x @ p["w_gate"][e]) * (x @ p["w_up"][e])
        outs.append(h @ p["w_down"][e])
    outs = jnp.stack(outs, 1)
    y = jnp.zeros_like(x)
    for k in range(m.top_k):
        y = y + w[:, k:k + 1] * jnp.take_along_axis(
            outs, idx[:, k][:, None, None], 1)[:, 0]
    return y + moe_lib._shared_expert(p, x, cfg.activation)


def test_sort_dispatch_matches_dense_oracle():
    cfg, p = _moe_setup()
    x = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model))
    y, _ = moe_lib.apply_moe_local(p, cfg, x, capacity_factor=8.0)
    y_ref = _moe_oracle(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5)


def test_padded_experts_never_selected():
    cfg, p = _moe_setup()
    e_pad = p["router"].shape[-1]
    if e_pad == cfg.moe.num_experts:
        pytest.skip("no padding for this config")
    x = jax.random.normal(jax.random.PRNGKey(2), (256, cfg.d_model))
    _, idx, _ = moe_lib._route(p, cfg.moe, x)
    assert int(jnp.max(idx)) < cfg.moe.num_experts


def test_capacity_drops_overflow():
    cfg, p = _moe_setup()
    x = jax.random.normal(jax.random.PRNGKey(3), (64, cfg.d_model))
    y_lo, _ = moe_lib.apply_moe_local(p, cfg, x, capacity_factor=0.05)
    y_hi, _ = moe_lib.apply_moe_local(p, cfg, x, capacity_factor=8.0)
    # low capacity drops tokens -> different (smaller-norm) output
    assert float(jnp.linalg.norm(y_lo)) < float(jnp.linalg.norm(y_hi))


def test_aux_loss_uniformity():
    from repro.models.moe import aux_load_balance_loss
    t, e = 1024, 8
    probs_u = jnp.full((t, e), 1.0 / e)
    idx_u = jnp.tile(jnp.arange(e), t // e).reshape(t, 1)
    uniform = float(aux_load_balance_loss(probs_u, idx_u, e))
    assert uniform == pytest.approx(1.0, abs=0.01)  # E * sum(1/E * 1/E)
    # a skewed router (all mass + all routing on expert 0) scores E x worse
    probs_s = jnp.zeros((t, e)).at[:, 0].set(1.0)
    idx_s = jnp.zeros((t, 1), jnp.int32)
    skew = float(aux_load_balance_loss(probs_s, idx_s, e))
    assert skew == pytest.approx(float(e), rel=0.01)
    assert skew > uniform


@given(st.integers(0, 10 ** 6))
@settings(max_examples=10, deadline=None)
def test_dispatch_indices_property(seed):
    """Every kept token lands in its expert's slot range, no slot clashes."""
    rng = np.random.default_rng(seed)
    t, k, e, cap = 64, 2, 8, 16
    idx = jnp.asarray(rng.integers(0, e, (t, k)))
    dest, src = moe_lib._dispatch_indices(idx, e, cap)
    dest = np.asarray(dest)
    kept = dest < e * cap
    experts = dest[kept] // cap
    flat_idx = np.asarray(idx).reshape(-1)
    np.testing.assert_array_equal(experts, flat_idx[kept])
    assert len(np.unique(dest[kept])) == kept.sum()  # unique slots


def test_mamba_chunked_matches_sequential():
    cfg = get_smoke_config("falcon-mamba-7b")
    f = ArrayFactory(jax.random.PRNGKey(0), False, jnp.float32)
    p = mamba_lib.make_mamba_params(f, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 50, cfg.d_model))
    out_c, cache = mamba_lib.mamba_prefill(p, cfg, x, chunk=16)
    out_f, cache2 = mamba_lib.mamba_prefill(p, cfg, x, chunk=64)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_f),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(cache["ssm"]),
                               np.asarray(cache2["ssm"]), atol=1e-4)


def test_mamba_decode_continues_prefill():
    cfg = get_smoke_config("falcon-mamba-7b")
    f = ArrayFactory(jax.random.PRNGKey(0), False, jnp.float32)
    p = mamba_lib.make_mamba_params(f, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 21, cfg.d_model))
    full, _ = mamba_lib.mamba_prefill(p, cfg, x)
    part, cache = mamba_lib.mamba_prefill(p, cfg, x[:, :20])
    step, cache2 = mamba_lib.mamba_decode(p, cfg, x[:, 20:21], cache)
    np.testing.assert_allclose(np.asarray(step[:, 0]),
                               np.asarray(full[:, 20]), atol=1e-3)
    assert cache2["conv"].shape == cache["conv"].shape
