"""Producer gear-switching semantics: §5 α-hysteresis + Eq.-5 property."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.cascade import Cascade
from repro.core.lp import Replica
from repro.core.simulator import ServingSimulator, SimConfig, make_gear
from repro.core.gears import Gear, GearPlan, SLO


def _plan(profiles, n_dev=2):
    reps = [Replica(m, d, profiles[m].runtime_per_sample(1.0))
            for m in profiles for d in range(n_dev)]
    from repro.core.gears import uniform_load_fractions
    names = sorted(profiles,
                   key=lambda m: profiles[m].runtime_per_sample(1.0))
    slow = make_gear(Cascade((names[-1],), ()), reps)   # accurate gear
    fast = make_gear(Cascade((names[0],), ()), reps)    # cheap gear
    return GearPlan(qps_max=1000.0, gears=[slow, fast], replicas=reps,
                    num_devices=n_dev,
                    slo=SLO(kind="latency", latency_p95=1.0)), reps


def test_upshift_on_spike_downshift_after(bert_like_profiles):
    plan, reps = _plan(bert_like_profiles)
    sim = ServingSimulator(bert_like_profiles, plan.replicas,
                           plan.num_devices)
    trace = np.concatenate([np.full(5, 50.0), np.full(5, 900.0),
                            np.full(10, 50.0)])
    res = sim.run_trace(plan, trace)
    kinds = [g for _, g in res.gear_switches]
    assert 1 in kinds          # upshifted to the fast gear during the spike
    assert kinds[-1] == 0      # and came back down afterwards
    t_up = next(t for t, g in res.gear_switches if g == 1)
    assert 4.9 <= t_up <= 6.0  # within a measurement interval of the spike


def test_hysteresis_defers_downshift(bert_like_profiles):
    """With a large backlog, qps < alpha * Q0 must hold the fast gear."""
    plan, reps = _plan(bert_like_profiles)
    # alpha=8 default; huge backlog via warm start at moderate qps
    sim = ServingSimulator(bert_like_profiles, plan.replicas,
                           plan.num_devices, SimConfig(alpha=8.0))
    # spike then silence: the backlog from the spike must drain in the
    # fast gear before any downshift
    trace = np.concatenate([np.full(3, 2000.0), np.full(6, 10.0)])
    res = sim.run_trace(plan, trace)
    downs = [t for t, g in res.gear_switches if g == 0]
    ups = [t for t, g in res.gear_switches if g == 1]
    assert ups and downs
    assert downs[-1] > 3.0  # not before the spike ends
    assert res.completed == res.offered


@given(st.integers(0, 10 ** 6), st.integers(2, 6), st.integers(2, 64))
@settings(max_examples=25, deadline=None)
def test_top2gap_nonnegative_and_shift_invariant(seed, b, v):
    """Eq. 5 properties: gap >= 0; invariant to additive logit shifts."""
    import jax.numpy as jnp
    from repro.core.certainty import top2_gap
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((b, v)).astype(np.float32)
    g1 = np.asarray(top2_gap(jnp.asarray(x)))
    assert (g1 >= 0).all()
    shift = rng.standard_normal((b, 1)).astype(np.float32)
    g2 = np.asarray(top2_gap(jnp.asarray(x + shift)))
    np.testing.assert_allclose(g1, g2, atol=1e-4)
