"""TokenEngine over the real kernels (DESIGN.md §13): multi-step greedy
decode parity vs the full forward, ragged (B,)-cache_index decode
equivalence, slot-pool join bit-identity, and mid-stream cascade
escalation carrying the prompt (never the cache)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.cascade import Cascade
from repro.core.gears import Gear
from repro.models import model as M
from repro.serving.token_engine import (SlotEngine, TokenEngine,
                                        TokenRequest, greedy_generate)


def _setup(arch, seed=0):
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    return cfg, params


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "falcon-mamba-7b"])
def test_greedy_decode_matches_forward(arch):
    """prefill + N x decode_step == full forward, position for position,
    along the greedy path (attention KV cache and mamba state cache)."""
    cfg, params = _setup(arch, seed=1)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, size=11).astype(np.int32)
    n_new = 5
    gen, gaps = greedy_generate(params, cfg, prompt, n_new)
    assert gen.shape == (n_new,) and gaps.shape == (n_new,)
    assert np.isfinite(gaps).all() and (gaps >= 0).all()
    # teacher-force the greedy tokens through the full forward: logits at
    # position L-1+k must match the k-th incremental-decode logits
    seq = np.concatenate([prompt, gen])[None, :]
    logits_full, _ = M.forward(params, cfg, {"tokens": jnp.asarray(seq)})
    logits_full = np.asarray(logits_full[0])
    L = prompt.size
    toks = jnp.asarray(prompt[None, :])
    step_logits, cache = M.prefill(params, cfg, {"tokens": toks},
                                   cache_len=L + n_new)
    for k in range(n_new):
        np.testing.assert_allclose(np.asarray(step_logits[0]),
                                   logits_full[L - 1 + k],
                                   atol=5e-2, rtol=0)
        assert int(np.argmax(np.asarray(step_logits[0]))) == int(gen[k])
        step = jnp.asarray([[int(gen[k])]], jnp.int32)
        step_logits, cache = M.decode_step(
            params, cfg, step, cache, jnp.asarray([L + k], jnp.int32))


def test_ragged_decode_matches_per_row():
    """decode_step with a (B,) cache_index equals per-row scalar decodes:
    the ragged batch is bit-invisible to each resident request."""
    cfg, params = _setup("qwen2-0.5b", seed=2)
    rng = np.random.default_rng(1)
    C = 32
    lens = [5, 11, 17]                      # three depths in one batch
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in lens]
    caches, solo = [], []
    nxt = rng.integers(0, cfg.vocab_size, size=3).astype(np.int32)
    for p, t in zip(prompts, nxt):
        _, c1 = M.prefill(params, cfg, {"tokens": jnp.asarray(p[None, :])},
                          cache_len=C)
        caches.append(c1)
        dl, _ = M.decode_step(params, cfg,
                              jnp.asarray([[int(t)]], jnp.int32), c1,
                              jnp.asarray(p.size, jnp.int32))
        solo.append(np.asarray(dl[0]))
    # stack the three b=1 caches into one ragged batch (batch axis 1 of
    # the rep-stacked cache arrays)
    batch_cache = jax.tree.map(
        lambda *xs: jnp.concatenate(xs, axis=1), *caches)
    dl, _ = M.decode_step(params, cfg, jnp.asarray(nxt[:, None]),
                          batch_cache, jnp.asarray(lens, jnp.int32))
    for b in range(3):
        np.testing.assert_array_equal(np.asarray(dl[b]), solo[b])


def test_slot_engine_join_bit_identity():
    """Requests joining a running decode batch get exactly the tokens a
    solo run produces (per-row ragged masks isolate the rows)."""
    cfg, params = _setup("qwen2-0.5b", seed=0)
    rng = np.random.default_rng(0)
    eng = SlotEngine("m", params, cfg, n_slots=4, max_len=40)
    gear = Gear(cascade=Cascade(("m",), ()), min_queue_lens={"m": 1},
                load_fractions={"m": {0: 1.0}})
    te = TokenEngine([eng], gear, min_tokens=2)
    reqs = [TokenRequest(i, rng.integers(0, cfg.vocab_size,
                                         10 + 3 * i).astype(np.int32), 6)
            for i in range(6)]     # 6 requests through 4 slots: real churn
    out = te.serve(reqs)
    for r in reqs:
        solo, sgaps = greedy_generate(params, cfg, r.prompt, r.max_new)
        assert out[r.rid].tokens == solo.tolist()
        assert out[r.rid].resolver == 0
        np.testing.assert_allclose(out[r.rid].gaps, sgaps,
                                   atol=5e-2, rtol=0)
    # slot pool fully recycled
    assert eng.n_active == 0 and sorted(eng.free) == [0, 1, 2, 3]


def test_slot_engine_validation():
    cfg, params = _setup("qwen2-0.5b", seed=0)
    eng = SlotEngine("m", params, cfg, n_slots=1, max_len=16)
    with pytest.raises(ValueError):
        eng.prefill_into_slot(np.arange(16, dtype=np.int32))  # no headroom
    slot, _ = eng.prefill_into_slot(np.arange(4, dtype=np.int32))
    with pytest.raises(RuntimeError):
        eng.prefill_into_slot(np.arange(4, dtype=np.int32))   # pool full
    eng.release(slot)
    with pytest.raises(ValueError):
        eng.release(slot)                                     # double free


def test_token_engine_midstream_escalation_restarts_from_prompt():
    """An uncertain stream escalates mid-generation; the next model gets
    the PROMPT (never the cache) and its output matches a solo run."""
    cfg, params_a = _setup("qwen2-0.5b", seed=0)
    _, params_b = _setup("qwen2-0.5b", seed=7)
    rng = np.random.default_rng(2)
    stages = [SlotEngine("a", params_a, cfg, n_slots=2, max_len=40),
              SlotEngine("b", params_b, cfg, n_slots=2, max_len=40)]
    # an unreachable threshold forces escalation at the first boundary
    # past min_tokens — every request must hop and resolve at stage 1
    gear = Gear(cascade=Cascade(("a", "b"), (1e9,)),
                min_queue_lens={"a": 1, "b": 1},
                load_fractions={"a": {0: 1.0}, "b": {1: 1.0}})
    te = TokenEngine(stages, gear, min_tokens=2, early_margin=0.5)
    reqs = [TokenRequest(i, rng.integers(0, cfg.vocab_size,
                                         8 + i).astype(np.int32), 6)
            for i in range(3)]
    out = te.serve(reqs)
    for r in reqs:
        assert out[r.rid].resolver == 1
        assert out[r.rid].hops >= 1
        solo, _ = greedy_generate(params_b, cfg, r.prompt, r.max_new)
        assert out[r.rid].tokens == solo.tolist()


def test_token_engine_rejects_mismatched_cascade():
    cfg, params = _setup("qwen2-0.5b", seed=0)
    eng = SlotEngine("x", params, cfg, n_slots=2, max_len=16)
    gear = Gear(cascade=Cascade(("y",), ()), min_queue_lens={"y": 1},
                load_fractions={"y": {0: 1.0}})
    with pytest.raises(ValueError):
        TokenEngine([eng], gear)
