"""Checkpoint manager: round trip (incl. bf16), retention, crash safety."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _tree():
    return {
        "w": jnp.asarray(np.random.default_rng(0).standard_normal((4, 8)),
                         jnp.bfloat16),
        "m": {"v": jnp.arange(5, dtype=jnp.float32),
              "step": jnp.asarray(7, jnp.int32)},
    }


def test_roundtrip_bf16(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(3, tree)
    restored, meta = mgr.restore(tree)
    assert meta["step"] == 3
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert str(np.asarray(a).dtype) == str(np.asarray(b).dtype)


def test_latest_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.latest_step() == 4
    assert mgr.all_steps() == [3, 4]  # retention pruned 1, 2


def test_crash_safety_tmp_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(1, tree)
    # simulate a crash mid-save: orphan tmp dir must not shadow LATEST
    os.makedirs(tmp_path / "step_000000002.tmp")
    assert mgr.latest_step() == 1
    restored, meta = mgr.restore(tree)
    assert meta["step"] == 1


def test_gear_plan_checkpointing(tmp_path, small_plan):
    report, _ = small_plan
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(10, {"x": jnp.zeros(1)}, gear_plan_json=report.plan.to_json())
    from repro.core import GearPlan
    js = mgr.restore_gear_plan()
    plan = GearPlan.from_json(js)
    assert plan.n_ranges == report.plan.n_ranges


def test_restore_missing_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        mgr.restore({"x": jnp.zeros(1)})
