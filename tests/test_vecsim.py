"""VecSim equivalence + Monte-Carlo certification tests.

The lane-batched engine (core/vecsim.py) is only usable because it is pinned
to the scalar ``ServingSimulator`` the same way fastsim was pinned to the
planner (DESIGN.md §10, §12): a single-lane VecSim run must be bit-identical
at the *decision-trace* level — every routing draw, batch firing, cascade
hop and gear switch, in order — on the five behavior-fingerprint scenarios.
Anything weaker would let the vectorized fast paths silently re-tune every
Monte-Carlo verdict the planner records.
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.core.cascade import Cascade
from repro.core.gears import GearPlan, PlanProvenance, SLO
from repro.core.lp import Replica
from repro.core.profiles import synthetic_family
from repro.core.scheduling import DecisionTrace
from repro.core.simulator import ServingSimulator, SimConfig, make_gear
from repro.core.vecsim import VecSim, mc_summary
from repro.distributed.fault_tolerance import HedgePolicy


def _family():
    return synthetic_family(["tiny", "mini", "base"], base_runtime=2e-4,
                            runtime_ratio=2.4, base_acc=0.70, acc_gain=0.06,
                            mem_base=0.4e9, seed=3)


@pytest.fixture(scope="module")
def world():
    profiles = _family()
    reps = [Replica(m, d, profiles[m].runtime_per_sample(1.0))
            for d in range(2) for m in profiles]
    g0 = make_gear(Cascade(("tiny", "base"), (0.35,)), reps, {"tiny": 2})
    g1 = make_gear(Cascade(("tiny", "mini"), (0.2,)), reps, {"tiny": 4})
    g2 = make_gear(Cascade(("tiny",), ()), reps, {"tiny": 8})
    plan = GearPlan(qps_max=600.0, gears=[g0, g1, g2], replicas=reps,
                    num_devices=2, slo=SLO(kind="latency", latency_p95=1.0))
    return profiles, reps, plan


def _digest(res):
    return {
        "completed": int(res.completed),
        "offered": int(res.offered),
        "backlog_end": int(res.backlog_end),
        "p95": float(res.p95),
        "accuracy": float(res.accuracy),
        "switches": len(res.gear_switches),
        "busy": float(res.device_busy.sum()),
    }


def _assert_equal(res_s, trace_s, res_v, trace_v, scenario):
    assert trace_v.routes == trace_s.routes, scenario
    assert trace_v.fires == trace_s.fires, scenario
    assert trace_v.hops == trace_s.hops, scenario
    assert trace_v.gear_switches == trace_s.gear_switches, scenario
    assert trace_v.swaps == trace_s.swaps, scenario
    assert _digest(res_v) == _digest(res_s), scenario
    assert res_v.gear_switches == res_s.gear_switches, scenario
    assert res_v.per_model_batches == res_s.per_model_batches, scenario
    assert res_v.per_model_samples == res_s.per_model_samples, scenario
    np.testing.assert_array_equal(res_v.latencies, res_s.latencies)
    np.testing.assert_array_equal(res_v.correct, res_s.correct)
    np.testing.assert_array_equal(res_v.resolver, res_s.resolver)
    np.testing.assert_array_equal(res_v.device_busy, res_s.device_busy)


def _pair(profiles, reps, cfg):
    return (ServingSimulator(profiles, reps, 2, cfg),
            VecSim(profiles, reps, 2, cfg))


# --------------------------------------------------------------------------
# the five fingerprint scenarios, decision-trace bit-identical
# --------------------------------------------------------------------------

def test_fixed_rate_bit_identical(world):
    profiles, reps, plan = world
    sim, vec = _pair(profiles, reps, SimConfig(max_batch=128))
    ts, tv = DecisionTrace(), DecisionTrace()
    qps, horizon = 300.0, 3.0
    arrivals = (np.arange(int(qps * horizon)) + 0.5) / qps
    # the scalar public run_fixed takes no trace; drive _run the way
    # run_fixed does (same arrivals, gear list, null selector)
    res_s = sim._run(arrivals, [plan.gears[0]], lambda t, q, g, q0: 0,
                     horizon=horizon, decision_trace=ts)
    res_v = vec.run_fixed(plan.gears[0], qps=qps, horizon=horizon,
                          decision_trace=tv)
    _assert_equal(res_s, ts, res_v, tv, "fixed-rate")


def test_fixed_rate_backlog_bit_identical(world):
    profiles, reps, plan = world
    sim, vec = _pair(profiles, reps, SimConfig(max_batch=128))
    res_s = sim.run_fixed(plan.gears[1], qps=420.0, horizon=2.0,
                          warm_start_backlog=105)
    res_v = vec.run_fixed(plan.gears[1], qps=420.0, horizon=2.0,
                          warm_start_backlog=105)
    assert _digest(res_v) == _digest(res_s)
    np.testing.assert_array_equal(res_v.latencies, res_s.latencies)


def test_trace_gear_switching_bit_identical(world):
    profiles, reps, plan = world
    sim, vec = _pair(profiles, reps, SimConfig(max_batch=128))
    trace = np.concatenate([np.full(3, 60.0), np.full(3, 550.0),
                            np.full(4, 60.0)])
    ts, tv = DecisionTrace(), DecisionTrace()
    res_s = sim.run_trace(plan, trace, decision_trace=ts)
    res_v = vec.run_trace(plan, trace, decision_trace=tv)
    _assert_equal(res_s, ts, res_v, tv, "trace")
    assert len(ts.gear_switches) > 0          # the scenario actually switches


def test_ensemble_bit_identical(world):
    profiles, reps, plan = world
    sim, vec = _pair(profiles, reps, SimConfig(max_batch=128))
    ens = make_gear(Cascade(("tiny", "mini", "base"), (0.0, 0.0)), reps,
                    mode="ensemble")
    ens_plan = GearPlan(qps_max=600.0, gears=[ens], replicas=reps,
                        num_devices=2, slo=plan.slo)
    ts, tv = DecisionTrace(), DecisionTrace()
    res_s = sim.run_trace(ens_plan, np.full(4, 80.0), decision_trace=ts)
    res_v = vec.run_trace(ens_plan, np.full(4, 80.0), decision_trace=tv)
    _assert_equal(res_s, ts, res_v, tv, "ensemble")


def test_device_failure_bit_identical(world):
    profiles, reps, plan = world
    sim, vec = _pair(profiles, reps, SimConfig(max_batch=128))
    ev = [(2.0, 0, "fail", 0.0), (9.0, 0, "recover", 1.0)]
    ts, tv = DecisionTrace(), DecisionTrace()
    res_s = sim.run_trace(plan, np.full(8, 50.0), device_events=ev,
                          drain=3.0, decision_trace=ts)
    res_v = vec.run_trace(plan, np.full(8, 50.0), device_events=ev,
                          drain=3.0, decision_trace=tv)
    _assert_equal(res_s, ts, res_v, tv, "device-failure")


def test_hedging_bit_identical(world):
    profiles, reps, plan = world
    sim, vec = _pair(profiles, reps, SimConfig(max_batch=128))
    ev = [(1.0, 1, "slow", 5.0), (6.0, 1, "recover", 1.0)]
    ts, tv = DecisionTrace(), DecisionTrace()
    res_s = sim.run_trace(plan, np.full(8, 60.0), device_events=ev,
                          drain=3.0, hedge=HedgePolicy(hedge_multiplier=3.0),
                          decision_trace=ts)
    res_v = vec.run_trace(plan, np.full(8, 60.0), device_events=ev,
                          drain=3.0, hedge=HedgePolicy(hedge_multiplier=3.0),
                          decision_trace=tv)
    _assert_equal(res_s, ts, res_v, tv, "hedging")


# --------------------------------------------------------------------------
# lane batching: every lane equals its scalar counterpart
# --------------------------------------------------------------------------

def test_lanes_match_scalar_per_seed(world):
    profiles, reps, plan = world
    cfg = SimConfig(max_batch=128)
    vec = VecSim(profiles, reps, 2, cfg)
    seeds = list(range(16))
    lanes = vec.run_fixed_lanes(plan.gears[0], qps=350.0, horizon=2.0,
                                warm_start_backlog=80, seeds=seeds)
    assert len(lanes) == 16
    for s in (2, 9):                     # spot-check two lanes bit-exactly
        sim = ServingSimulator(profiles, reps, 2,
                               dataclasses.replace(cfg, seed=s))
        res = sim.run_fixed(plan.gears[0], qps=350.0, horizon=2.0,
                            warm_start_backlog=80)
        assert _digest(lanes[s]) == _digest(res)
        np.testing.assert_array_equal(lanes[s].latencies, res.latencies)


def test_seed_sensitivity_within_reported_ci(world):
    """Property test guarding the seed plumbing: two fresh scalar runs with
    different RoutePool seeds must land inside the lane-population band and
    inside a 3x-widened CI of the vecsim-reported p95 distribution (the CI
    is a statement about the mean; individual seeds get the 3x band)."""
    profiles, reps, plan = world
    cfg = SimConfig(max_batch=128)
    vec = VecSim(profiles, reps, 2, cfg)
    seeds = list(range(24))
    lanes = vec.run_fixed_lanes(plan.gears[0], qps=400.0, horizon=2.0,
                                warm_start_backlog=100, seeds=seeds)
    p95s = [r.p95 for r in lanes]
    mean, ci = mc_summary(p95s)
    assert math.isfinite(mean) and ci >= 0.0
    lo, hi = min(p95s), max(p95s)
    for s in (31, 77):                   # seeds OUTSIDE the lane set
        sim = ServingSimulator(profiles, reps, 2,
                               dataclasses.replace(cfg, seed=s))
        p = sim.run_fixed(plan.gears[0], qps=400.0, horizon=2.0,
                          warm_start_backlog=100).p95
        spread = max(3.0 * ci, hi - lo)
        assert mean - spread <= p <= mean + spread, \
            (s, p, mean, ci, lo, hi)


def test_mc_summary_edge_cases():
    mean, ci = mc_summary([])
    assert mean == math.inf
    mean, ci = mc_summary([0.25])
    assert (mean, ci) == (0.25, 0.0)
    mean, ci = mc_summary([0.2, math.inf])
    assert mean == math.inf and ci == math.inf
    mean, ci = mc_summary([0.2, 0.3, 0.4])
    assert abs(mean - 0.3) < 1e-12 and ci > 0.0


# --------------------------------------------------------------------------
# Monte-Carlo certification through the planner
# --------------------------------------------------------------------------

def _plan_pair(num_seeds):
    from repro.core.plan_state import HardwareSpec
    from repro.core.planner import optimize_gear_plan
    profiles = _family()
    hw = HardwareSpec(num_devices=2, mem_per_device=2e9)
    slo = SLO(kind="latency", latency_p95=1.0)
    return optimize_gear_plan(profiles, hw, slo, qps_max=300.0, n_ranges=3,
                              num_seeds=num_seeds)


def test_mc_certification_same_plan_with_ci_provenance():
    """num_seeds>1 must not change the certified plan at all — only widen
    its provenance with the per-range (mean, CI) p95 distribution."""
    r1 = _plan_pair(1)
    rm = _plan_pair(6)
    d1, dm = r1.plan.to_dict(), rm.plan.to_dict()
    d1.pop("provenance"), dm.pop("provenance")
    assert d1 == dm                      # identical plan, gears, placement
    stats = r1.memo_stats                # satellite: memo hit-rate counters
    assert set(stats) == {"sim_memo", "lp_memo", "place_memo"}
    assert all(h >= 0 and m > 0 for h, m in stats.values())
    assert r1.plan.provenance.mc_p95 == ()
    assert r1.plan.provenance.mc_seeds == 1
    prov = rm.plan.provenance
    assert prov.mc_seeds == 6
    assert len(prov.mc_p95) == 3
    for (mean, ci), point in zip(prov.mc_p95, rm.state.range_p95):
        assert math.isfinite(mean) and ci >= 0.0
        # lane 0 IS the certified seed, so the point estimate must lie
        # inside the sampled band
        assert mean - 6 * ci - 1e-9 <= point <= mean + 6 * ci + 1e-9


def test_mc_provenance_round_trip():
    prov = PlanProvenance(
        qps_max=100.0, n_ranges=2, qps_prior=(0.7, 0.3), num_devices=2,
        mem_per_device=1e9, mc_p95=((0.01, 0.002), (0.02, 0.001)),
        mc_seeds=16)
    back = PlanProvenance.from_dict(prov.to_dict())
    assert back == prov
    # pre-MC serialized plans (no mc fields) still load, with defaults
    d = prov.to_dict()
    d.pop("mc_p95"), d.pop("mc_seeds")
    old = PlanProvenance.from_dict(d)
    assert old.mc_p95 == () and old.mc_seeds == 1


def test_monitor_latency_drift_ci_keyed():
    """The CI-keyed p95 drift check: observed p95 beyond the certified
    band -> one latency-drift trigger, re-armed on recovery; plans without
    an MC band (or factor 0) never trigger."""
    from repro.core.adaption import MonitorConfig, PlanMonitor
    prov = PlanProvenance(
        qps_max=100.0, n_ranges=1, qps_prior=(1.0,), num_devices=2,
        mem_per_device=1e9, mc_p95=((0.100, 0.010),), mc_seeds=8)
    cfg = MonitorConfig(p95_drift_factor=2.0, p95_min_samples=10,
                        cooldown=0.0)
    mon = PlanMonitor(prov, cfg)
    # threshold = mean + 2*ci = 0.12; feed latencies far above it
    for _ in range(20):
        mon.observe_latency(0.2)
    trig = mon.on_tick(1.0, measured_qps=10.0)
    assert trig is not None and trig.reason == "latency-drift"
    assert mon.on_tick(2.0, measured_qps=10.0) is None   # report once
    for _ in range(500):
        mon.observe_latency(0.05)                        # recover
    assert mon.on_tick(3.0, measured_qps=10.0) is None   # re-armed quietly
    for _ in range(600):
        mon.observe_latency(0.5)                         # drift again
    trig = mon.on_tick(4.0, measured_qps=10.0)
    assert trig is not None and trig.reason == "latency-drift"
    # too few samples: silent
    mon2 = PlanMonitor(prov, cfg)
    for _ in range(5):
        mon2.observe_latency(10.0)
    assert mon2.on_tick(1.0, measured_qps=10.0) is None
    # no MC band or disabled factor: the check never arms
    flat = PlanProvenance(qps_max=100.0, n_ranges=1, qps_prior=(1.0,),
                          num_devices=2, mem_per_device=1e9)
    mon3 = PlanMonitor(flat, cfg)
    for _ in range(20):
        mon3.observe_latency(10.0)
    assert mon3.on_tick(1.0, measured_qps=10.0) is None
    mon4 = PlanMonitor(prov, MonitorConfig(p95_min_samples=10,
                                           cooldown=0.0))
    for _ in range(20):
        mon4.observe_latency(10.0)
    assert mon4.on_tick(1.0, measured_qps=10.0) is None


def test_validation_errors():
    """The PR 3/5 ValueError convention on the new and touched surfaces."""
    from repro.core.traces import (measured_qps_distribution, spiky_trace,
                                   zipf_prior)
    profiles = _family()
    reps = [Replica(m, d, profiles[m].runtime_per_sample(1.0))
            for d in range(2) for m in profiles]
    vec = VecSim(profiles, reps, 2, SimConfig())
    sim = ServingSimulator(profiles, reps, 2, SimConfig())
    g = make_gear(Cascade(("tiny",), ()), reps, {"tiny": 1})
    for runner in (vec, sim):
        with pytest.raises(ValueError):
            runner.run_fixed(g, qps=-1.0)
        with pytest.raises(ValueError):
            runner.run_fixed(g, qps=10.0, horizon=0.0)
        with pytest.raises(ValueError):
            runner.run_fixed(g, qps=10.0, warm_start_backlog=-1)
    with pytest.raises(ValueError):
        VecSim(profiles, reps, 0)
    with pytest.raises(ValueError):
        ServingSimulator(profiles, reps, 0)
    with pytest.raises(ValueError):
        vec.run_fixed_lanes(g, qps=10.0, seeds=())
    with pytest.raises(ValueError):
        zipf_prior(0)
    with pytest.raises(ValueError):
        spiky_trace(seconds=0)
    with pytest.raises(ValueError):
        measured_qps_distribution(np.array([1.0]), 0, 10.0)
    with pytest.raises(ValueError):
        measured_qps_distribution(np.array([]), 2, 10.0)
    from repro.core.planner import make_state
    from repro.core.plan_state import HardwareSpec
    with pytest.raises(ValueError):
        make_state(profiles, HardwareSpec(2, 2e9),
                   SLO(kind="latency", latency_p95=1.0), qps_max=100.0,
                   num_seeds=0)
