"""Unified telemetry layer (core/telemetry.py, DESIGN.md §16): histogram
determinism + quantile readback, registry exporters, span-close
accounting under hedges and drain->revoke, cross-driver byte-identical
exports, attribution reconciliation, and the PlanMonitor p95 fallback."""
import warnings

import numpy as np
import pytest

from repro.core.adaption import MonitorConfig, PlanMonitor
from repro.core.cascade import Cascade
from repro.core.execution import ReplayBackend, TokenReplayBackend
from repro.core.gears import Gear, GearPlan, PlanProvenance, SLO
from repro.core.lp import Replica
from repro.core.profiles import synthetic_family, synthetic_token_family
from repro.core.simulator import (ServingSimulator, SimConfig, make_gear,
                                  trace_to_arrivals)
from repro.core.telemetry import (Log2Histogram, MetricsRegistry, Span,
                                  SpanAccountingError, Telemetry)
from repro.core.vecsim import VecSim
from repro.distributed.fault_tolerance import HedgePolicy

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402


# ---------------------------------------------------------------------------
# Log2Histogram
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(st.lists(st.floats(min_value=1e-6, max_value=1e4),
                min_size=1, max_size=200),
       st.floats(min_value=0.0, max_value=1.0))
def test_histogram_quantile_within_one_bucket(values, q):
    """quantile() (nearest-rank-up, bucket upper edge) brackets
    ``np.percentile(..., method='higher')`` from above, within one
    relative bucket width (1/subs of the value)."""
    h = Log2Histogram(subs=8)
    for v in values:
        h.observe(v)
    exact = float(np.percentile(values, 100.0 * q, method="higher"))
    got = h.quantile(q)
    assert exact <= got <= exact * (1.0 + 1.0 / h.subs) + 1e-12


def test_histogram_zero_negative_bucket_and_mean():
    h = Log2Histogram()
    for v in (-1.0, 0.0, 2.0, 4.0):
        h.observe(v)
    assert h.zero_neg == 2
    assert h.n == 4
    assert h.mean == pytest.approx((-1.0 + 0.0 + 2.0 + 4.0) / 4)
    assert h.quantile(0.0) == 0.0            # <=0 observations sort first


def test_histogram_snapshot_deterministic():
    rng = np.random.default_rng(11)
    vals = rng.lognormal(-3.0, 1.0, size=500)
    a, b = Log2Histogram(), Log2Histogram()
    for v in vals:
        a.observe(float(v))
        b.observe(float(v))
    assert a.snapshot() == b.snapshot()


# ---------------------------------------------------------------------------
# MetricsRegistry
# ---------------------------------------------------------------------------

def _feed(reg: MetricsRegistry):
    reg.counter("reqs", tenant="a").inc(3)
    reg.gauge("qps").set(123.5)
    h = reg.histogram("lat", gear="0")
    for v in (0.01, 0.02, 0.04, 0.08):
        h.observe(v)
    s = reg.series("win", maxlen=8)
    for v in (1.0, 2.0, 3.0):
        s.observe(v)


def test_registry_exports_byte_identical():
    a, b = MetricsRegistry(), MetricsRegistry()
    _feed(a)
    _feed(b)
    assert a.export_jsonl() == b.export_jsonl()
    assert a.prometheus_text() == b.prometheus_text()
    # exporters carry every metric type
    text = a.prometheus_text()
    assert '# TYPE reqs counter' in text
    assert 'lat_bucket{gear="0",le="+Inf"} 4' in text
    assert 'win_count 3' in text


def test_registry_type_conflict_raises():
    reg = MetricsRegistry()
    reg.counter("m")
    with pytest.raises(TypeError):
        reg.gauge("m")


# ---------------------------------------------------------------------------
# Span accounting (cold-path API)
# ---------------------------------------------------------------------------

def test_span_double_close_raises():
    t = Telemetry()
    t.admit(0.0, 7)
    t.close(1.0, 7, "completed")
    t.close(2.0, 7, "shed")
    with pytest.raises(SpanAccountingError, match="closed twice"):
        t.finalize()


def test_close_without_admit_raises():
    t = Telemetry()
    t.close(1.0, 3, "completed")
    with pytest.raises(SpanAccountingError, match="never admitted"):
        t.finalize()
    t2 = Telemetry()
    t2.raw.append(("closeb", 1.0, [4]))
    with pytest.raises(SpanAccountingError, match="never admitted"):
        t2.finalize()


def test_unknown_close_state_raises():
    t = Telemetry()
    t.admit(0.0, 1)
    t.close(1.0, 1, "vanished")
    with pytest.raises(SpanAccountingError, match="unknown close state"):
        t.finalize()


def test_post_close_events_dropped():
    """A hedge duplicate completing after the primary resolved must not
    extend the span past t_close (the telescoping sum would break)."""
    t = Telemetry()
    t.admit(0.0, 1)
    t.raw.append(("fire", 0.5, 0, [1]))
    t.close(1.0, 1, "completed")
    t.raw.append(("fire", 1.5, 0, [1]))      # straggler duplicate
    t.finalize()
    sp = t.spans[1]
    assert all(ev[1] <= sp.t_close for ev in sp.events)
    assert sum(sp.components().values()) == pytest.approx(sp.latency)


def test_escb_folds_like_per_sid_escalates():
    """The batched escalation event is pure hot-path economy: it must
    fold to the same spans as per-sid escalate events."""
    a, b = Telemetry(), Telemetry()
    for t in (a, b):
        t.admit(0.0, 1)
        t.admit(0.0, 2)
    a.raw.append(("escb", 0.5, [1, 2], [0, 0]))
    b.event("escalate", 0.5, 1, 0)
    b.event("escalate", 0.5, 2, 0)
    for t in (a, b):
        t.close(1.0, 1, "completed")
        t.close(1.0, 2, "completed")
        t.finalize()
    assert {k: v.to_dict() for k, v in a.spans.items()} == \
        {k: v.to_dict() for k, v in b.spans.items()}


def test_same_instant_fire_sorts_after_queue_enter():
    """Canonical event order: a queue-class event and a fire at the same
    timestamp fold causally (queue before fire) regardless of raw-log
    order, so attribution labels the following interval as execute."""
    spans = []
    for order in (("escalate", "fire"), ("fire", "escalate")):
        t = Telemetry()
        t.admit(0.0, 1)
        t.raw.append(("fire", 0.2, 0, [1]))
        for kind in order:
            if kind == "fire":
                t.raw.append(("fire", 0.5, 1, [1]))
            else:
                t.event("escalate", 0.5, 1, 0)
        t.close(1.0, 1, "completed")
        t.finalize()
        spans.append(t.spans[1].to_dict())
    assert spans[0] == spans[1]
    sp = Span(1, 0.0, 0, 0, "")
    sp.events = [("escalate", 0.5, 0), ("fire", 0.5, 0)]
    sp.state, sp.t_close = "completed", 1.0
    assert sp.components()["execute"] == pytest.approx(0.5)


def test_tenant_labels_flow_to_attribution():
    t = Telemetry()
    for i, tenant in enumerate(("interactive", "batch", "interactive")):
        t.admit(float(i), i, gear=0, tenant=tenant)
        t.close(float(i) + 0.5, i, "completed")
    attr = t.attribution()
    assert set(attr["by_tenant"]) == {"interactive", "batch"}
    assert attr["by_tenant"]["interactive"]["count"] == 2
    table = Telemetry.render_attribution(attr)
    assert "tenant=interactive" in table and "TOTAL" in table


# ---------------------------------------------------------------------------
# Cross-driver identity + conservation (scalar DES vs VecSim lanes)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def telem_world():
    profiles = synthetic_family(
        ["tiny", "mini", "base"], base_runtime=2e-4, runtime_ratio=2.4,
        base_acc=0.70, acc_gain=0.06, mem_base=0.4e9, seed=3)
    reps = [Replica(m, d, profiles[m].runtime_per_sample(1.0))
            for d in range(2) for m in profiles]
    g0 = make_gear(Cascade(("tiny", "base"), (0.35,)), reps, {"tiny": 4})
    g1 = make_gear(Cascade(("tiny", "mini"), (0.2,)), reps, {"tiny": 8})
    plan = GearPlan(qps_max=1200.0, gears=[g0, g1], replicas=reps,
                    num_devices=2, slo=SLO(kind="latency", latency_p95=1.0))
    trace = np.concatenate([np.full(6, 300.0), np.full(6, 900.0),
                            np.full(6, 300.0)])
    return profiles, reps, plan, trace


SCENARIOS = {
    "plain": {},
    "spot_hedge": dict(
        device_events=[(4.0, 1, "slow", 8.0), (8.0, 1, "recover", 1.0),
                       (10.0, 0, "drain", 0.5), (10.5, 0, "revoke", 0.0)],
        hedge=HedgePolicy(hedge_multiplier=2.0)),
}


@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_cross_driver_telemetry_bitmatch(telem_world, scenario):
    """Same trace through the scalar DES and the lane-batched VecSim:
    identical latencies (pure observer), byte-identical JSONL exports,
    identical folded spans, exact conservation against the SimResult,
    and attribution groups that reconcile to ~1e-14."""
    profiles, reps, plan, trace = telem_world
    kw = SCENARIOS[scenario]
    cfg = SimConfig(max_batch=64)
    backend = ReplayBackend(profiles)

    ts = Telemetry()
    sim = ServingSimulator(profiles, reps, 2, cfg, backend=backend,
                           telemetry=ts)
    rs = sim.run_trace(plan, trace, **kw)
    ts.finalize()

    tv = Telemetry()
    vec = VecSim(profiles, reps, 2, cfg, backend=backend, telemetry=tv)
    rv = vec.run_trace(plan, trace, **kw)
    tv.finalize()

    # telemetry is a pure observer: not one decision moved
    np.testing.assert_array_equal(rs.latencies, rv.latencies)
    # byte-identical registry export and identical folded spans
    assert ts.registry.export_jsonl() == tv.registry.export_jsonl()
    assert {k: v.to_dict() for k, v in ts.spans.items()} == \
        {k: v.to_dict() for k, v in tv.spans.items()}
    # conservation: spans_closed == completed + shed, remainder open
    cons = ts.conservation()
    assert cons["opened"] == rs.offered
    assert cons["completed"] == rs.completed
    assert cons["revoked"] + cons["shed"] == rs.shed
    assert cons["open"] == rs.backlog_end
    if scenario == "spot_hedge":
        assert cons["revoked"] > 0           # the drain->revoke fired
    # telescoping attribution reconciles per group
    attr = ts.attribution(window_s=5.0)
    groups = [attr["total"]] + list(attr["by_gear"].values()) + \
        list(attr["by_tenant"].values()) + list(attr["by_window"].values())
    for g in groups:
        if g["count"]:
            assert sum(g["components"].values()) == \
                pytest.approx(g["end_to_end"], rel=1e-9)


def test_fixed_run_span_exports_deterministic(telem_world):
    """Two identical scalar runs produce byte-identical span JSONL and
    registry JSONL (no wall clock, no RNG in the telemetry layer)."""
    profiles, reps, plan, trace = telem_world
    outs = []
    for _ in range(2):
        t = Telemetry()
        sim = ServingSimulator(profiles, reps, 2, SimConfig(max_batch=64),
                               backend=ReplayBackend(profiles), telemetry=t)
        sim.run_fixed(plan.gears[0], qps=400.0, horizon=1.0)
        t.finalize()
        outs.append((t.registry.export_jsonl(),
                     t.export_spans_jsonl(limit=50)))
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# Threaded-runtime (virtual clock) spans
# ---------------------------------------------------------------------------

def test_runtime_virtual_span_conservation(telem_world):
    from repro.serving.runtime import CascadeServer, Request
    profiles, reps, plan, trace = telem_world
    telem = Telemetry()
    server = CascadeServer(plan, backend=ReplayBackend(profiles),
                           max_batch=64, telemetry=telem)
    n = len(trace_to_arrivals(trace))
    reqs = [Request(rid=i, tokens=np.array([i], np.int64))
            for i in range(n)]
    done = server.run_virtual(
        reqs, trace, batch_runtime=lambda m, b: profiles[m].runtime(b))
    telem.finalize()
    cons = telem.conservation()
    assert cons["opened"] == n
    assert cons["completed"] == len(done)
    assert cons["open"] == n - len(done) - cons["revoked"] - cons["shed"]
    # spans carry the gear tag and components reconcile
    attr = telem.attribution()
    assert attr["total"]["count"] == len(done)
    assert sum(attr["total"]["components"].values()) == \
        pytest.approx(attr["total"]["end_to_end"], rel=1e-9)


# ---------------------------------------------------------------------------
# Token path spans
# ---------------------------------------------------------------------------

def test_token_trace_span_conservation():
    toks = synthetic_token_family(["s", "l"], base_step=2e-4,
                                  step_ratio=3.0, seed=7)
    backend = TokenReplayBackend(toks)
    gear = Gear(cascade=Cascade(("s", "l"), (0.55,)),
                min_queue_lens={"s": 1, "l": 1},
                load_fractions={"s": {0: 1.0}, "l": {1: 1.0}},
                decode_slots={"s": 8, "l": 8},
                kv_bytes_per_slot={m: toks[m].kv_bytes_per_slot
                                   for m in toks})
    telem = Telemetry()
    sim = ServingSimulator(synthetic_family(["s", "l"], seed=7),
                           [Replica("s", 0, 2e-4), Replica("l", 1, 6e-4)],
                           2, SimConfig(max_batch=16, max_wait=0.02),
                           telemetry=telem)
    rng = np.random.default_rng(3)
    arrivals = np.cumsum(rng.exponential(1 / 150.0, size=200))
    plens = rng.integers(16, 128, size=200)
    r = sim.run_token_trace(gear, arrivals, plens, backend,
                            mode="continuous", n_slots=8)
    telem.finalize()
    cons = telem.conservation()
    assert cons["opened"] == len(arrivals)
    assert cons["completed"] == r.completed
    # the token path feeds TTFT/TPOT histograms with exact readback
    fam = telem.registry.family("token_ttft")
    assert any(m.n > 0 for m in fam.values())


# ---------------------------------------------------------------------------
# PlanMonitor p95 fallback (MonitorConfig.p95_drift_factor satellite)
# ---------------------------------------------------------------------------

def _prov(**kw):
    return PlanProvenance(qps_max=100.0, n_ranges=1, qps_prior=(1.0,),
                          num_devices=2, mem_per_device=1e9, **kw)


def test_monitor_p95_scalar_fallback_arms_the_check():
    """Single-seed plans (empty mc_p95) fall back to the scalar certified
    p95 + absolute margin instead of silently disarming."""
    prov = _prov(range_p95=(0.100,))
    cfg = MonitorConfig(p95_drift_factor=2.0, p95_min_samples=10,
                        p95_abs_margin=0.05, cooldown=0.0)
    with warnings.catch_warnings():
        warnings.simplefilter("error")       # fallback must not warn
        mon = PlanMonitor(prov, cfg)
    assert mon._p95_mode == "scalar"
    assert mon._p95_threshold == pytest.approx(0.15)
    for _ in range(20):
        mon.observe_latency(0.30)            # far past 0.15
    trig = mon.on_tick(1.0, measured_qps=10.0)
    assert trig is not None and trig.reason == "latency-drift"
    # below the fallback threshold: quiet
    mon2 = PlanMonitor(prov, cfg)
    for _ in range(20):
        mon2.observe_latency(0.12)
    assert mon2.on_tick(1.0, measured_qps=10.0) is None


def test_monitor_p95_warns_once_when_disarmed():
    cfg = MonitorConfig(p95_drift_factor=2.0, p95_min_samples=10)
    with pytest.warns(RuntimeWarning, match="latency-drift check is "
                                            "disarmed"):
        mon = PlanMonitor(_prov(), cfg)
    assert mon._p95_threshold is None
    with warnings.catch_warnings():
        warnings.simplefilter("error")       # second rebase: no re-warn
        mon.rebase(_prov(), t=1.0)
