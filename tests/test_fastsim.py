"""Fast planner evaluation layer (core/fastsim.py, DESIGN.md §10):
vectorized helpers are bit-identical to the scalar paths they replace, the
exact-DES memo cache is keyed on the FULL SimConfig (calibration changes
can never serve stale results) and digest-guarded across warm starts, and
the fast-path planner produces plans identical to the pre-change search on
the planner test scenarios."""
import dataclasses

import numpy as np
import pytest

from repro.core import (Gear, HardwareSpec, SLO, SimConfig,
                        optimize_gear_plan)
from repro.core.cascade import Cascade, evaluate_cascade
from repro.core.fastsim import (FastEvaluator, SimMemo, SimOutcome,
                                cascade_throughputs, model_capacities,
                                sim_memo_key, trigger_ladder)
from repro.core.lp import Replica
from repro.core.profiles import synthetic_family
from repro.core.simulator import trace_to_arrivals


# ---------------------------------------------------------------------------
# trace_to_arrivals vectorization (satellite): equivalence with the
# per-second loop it replaced
# ---------------------------------------------------------------------------

def _arrivals_loop(qps_per_sec):
    out = []
    for s, q in enumerate(np.asarray(qps_per_sec)):
        k = int(round(q))
        if k > 0:
            out.append(s + (np.arange(k) + 0.5) / k)
    return np.concatenate(out) if out else np.zeros(0)


@pytest.mark.parametrize("trace", [
    np.zeros(5),
    np.array([1.0]),
    np.array([0.0, 3.7, 0.2, 12.5, 0.49, 400.0]),
    np.full(30, 7.0),
    np.array([2.5, 3.5]),                     # banker's-rounding edge
])
def test_trace_to_arrivals_matches_loop(trace):
    assert np.array_equal(trace_to_arrivals(trace), _arrivals_loop(trace))


def test_trace_to_arrivals_random_traces():
    rng = np.random.default_rng(0)
    for _ in range(20):
        trace = rng.uniform(0, 50, size=rng.integers(1, 40)) * \
            rng.integers(0, 2, size=1)
        assert np.array_equal(trace_to_arrivals(trace),
                              _arrivals_loop(trace))


# ---------------------------------------------------------------------------
# Memo cache keying (satellite guard): the FULL SimConfig is in the key
# ---------------------------------------------------------------------------

def _mk_gear():
    return Gear(cascade=Cascade(("a", "b"), (0.5,)),
                min_queue_lens={"a": 1, "b": 1},
                load_fractions={"a": {0: 1.0}, "b": {1: 1.0}})


def _mk_replicas():
    return [Replica("a", 0, 1e-3), Replica("b", 1, 5e-3)]


def test_memo_key_covers_every_simconfig_field():
    """Any calibration change — dispatch overhead, max-wait, hysteresis,
    seed, batch cap, measurement interval — must produce a different memo
    key, so a re-plan after re-calibration can never reuse stale DES
    outcomes."""
    gear, reps = _mk_gear(), _mk_replicas()
    base_cfg = SimConfig()
    base = sim_memo_key(gear, 100.0, 2.0, 25, base_cfg, reps, 2)
    for f in dataclasses.fields(SimConfig):
        bumped = dataclasses.replace(
            base_cfg, **{f.name: getattr(base_cfg, f.name) + 1})
        key = sim_memo_key(gear, 100.0, 2.0, 25, bumped, reps, 2)
        assert key != base, f"SimConfig.{f.name} not part of the memo key"


def test_memo_key_sensitive_to_gear_and_workload():
    gear, reps = _mk_gear(), _mk_replicas()
    cfg = SimConfig()
    base = sim_memo_key(gear, 100.0, 2.0, 25, cfg, reps, 2)
    other = Gear(cascade=gear.cascade, min_queue_lens={"a": 2, "b": 1},
                 load_fractions=gear.load_fractions)
    assert sim_memo_key(other, 100.0, 2.0, 25, cfg, reps, 2) != base
    assert sim_memo_key(gear, 101.0, 2.0, 25, cfg, reps, 2) != base
    assert sim_memo_key(gear, 100.0, 2.0, 26, cfg, reps, 2) != base
    moved = [Replica("a", 1, 1e-3), Replica("b", 0, 5e-3)]
    assert sim_memo_key(gear, 100.0, 2.0, 25, cfg, moved, 2) != base


def test_memo_carry_is_profile_digest_guarded():
    fam = synthetic_family(["a", "b"], seed=1)
    old = SimMemo()
    old.set_profiles(fam)
    gear, reps = _mk_gear(), _mk_replicas()
    key = sim_memo_key(gear, 100.0, 2.0, 25, SimConfig(), reps, 2)
    old.put(key, SimOutcome(stable=True, p95=0.1))

    # same profiles: the entry transfers
    new = SimMemo()
    new.carry_from(old, fam)
    assert new.get(key) is not None

    # a model the entry touches was re-profiled: the entry must NOT serve
    drifted = synthetic_family(["a", "b"], seed=2)
    new2 = SimMemo()
    new2.carry_from(old, drifted)
    assert new2.get(key) is None

    # pinned re-plan sees a SUBSET of the profiles: entries over surviving
    # models still transfer, entries over dropped models do not
    subset = {"a": fam["a"], "b": fam["b"]}
    only_a_gear = Gear(cascade=Cascade(("a",), ()),
                       min_queue_lens={"a": 1},
                       load_fractions={"a": {0: 1.0}})
    key_a = sim_memo_key(only_a_gear, 50.0, 2.0, 12, SimConfig(),
                         [Replica("a", 0, 1e-3)], 1)
    old.put(key_a, SimOutcome(stable=True, p95=0.05))
    new3 = SimMemo()
    new3.carry_from(old, {"a": subset["a"]})
    assert new3.get(key_a) is not None
    assert new3.get(key) is None      # touches 'b', absent from the subset


# ---------------------------------------------------------------------------
# Vectorized helpers: bit-identical to the scalar paths
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def family():
    return synthetic_family(["s", "m", "l"], base_runtime=1e-3,
                            runtime_ratio=3.0, seed=7)


def test_batch_runtimes_matches_profile_runtime(family):
    ev = FastEvaluator(family)
    batches = np.array([0.5, 1, 2, 3, 5, 17, 64, 100, 512, 2000.0])
    for m, prof in family.items():
        vec = ev.batch_runtimes(m, batches)
        ref = np.array([prof.runtime(b) for b in batches])
        assert np.array_equal(vec, ref)


def test_cascade_throughputs_bit_identical(family):
    from repro.core.planner import make_state
    from repro.core.submodules.cascade_search import estimate_throughput
    hw = HardwareSpec(num_devices=3, mem_per_device=64e9)
    state = make_state(family, hw, SLO(kind="latency", latency_p95=1.0),
                       qps_max=100.0, n_ranges=4)
    cascades = [Cascade(("s",), ()), Cascade(("s", "l"), (0.4,)),
                Cascade(("s", "m", "l"), (0.3, 0.6)), Cascade(("l",), ())]
    evals = [evaluate_cascade(c, family) for c in cascades]
    vec = cascade_throughputs(family, hw.num_devices, cascades, evals)
    ref = [estimate_throughput(state, e, c)
           for c, e in zip(cascades, evals)]
    assert vec == ref      # exact float equality, not approx


def test_model_capacities_matches_scan():
    reps = [Replica("a", 0, 1e-3), Replica("b", 0, 2e-3),
            Replica("a", 1, 1e-3), Replica("b", 1, 2e-3)]
    caps = model_capacities(reps)
    for m in ("a", "b"):
        ref = sum(1.0 / r.runtime_per_sample for r in reps if r.model == m)
        assert caps[m] == ref


def test_trigger_ladder_matches_growth_rule():
    ladder = trigger_ladder(128)
    assert ladder[0] == 1 and ladder[-1] == 128
    mq, ref = 1, [1]
    while mq < 128:
        mq = min(128, max(mq + 1, int(mq * 1.5)))
        ref.append(mq)
    assert ladder == ref


def test_evaluate_ladder_sanity(family):
    ev = FastEvaluator(family)
    casc = Cascade(("s", "l"), (0.4,))
    ce = evaluate_cascade(casc, family)
    reps = [Replica("s", 0, 1e-3), Replica("l", 1, 9e-3)]
    lf = {"s": {0: 1.0}, "l": {1: 1.0}}
    ladder = trigger_ladder()
    # light load: every trigger stable, finite p95
    fe = ev.evaluate_ladder(casc, ce, lf, reps, 2, qps=5.0, cfg=SimConfig(),
                            triggers=ladder, offered=100.0)
    assert fe.stable.all() and np.isfinite(fe.p95).all()
    assert fe.accuracy == ce.accuracy
    # far beyond aggregate capacity: nothing is stable
    fe2 = ev.evaluate_ladder(casc, ce, lf, reps, 2, qps=1e6,
                             cfg=SimConfig(), triggers=ladder,
                             offered=2e6)
    assert not fe2.stable.any()
    # heavy per-batch overhead at trigger 1 under moderate load (batches
    # stay trigger-bound): raising the trigger amortises the overhead —
    # the §4.5 sweep's raison d'etre
    cfg_ovh = SimConfig(dispatch_overhead=5e-3)
    fe3 = ev.evaluate_ladder(casc, ce, lf, reps, 2, qps=150.0, cfg=cfg_ovh,
                             triggers=ladder, offered=300.0)
    assert fe3.util[0] > fe3.util[6]


# ---------------------------------------------------------------------------
# Plan parity: fast path == pre-change planner on the test scenarios
# ---------------------------------------------------------------------------

def plan_signature(report):
    return (
        [tuple(g.cascade.models) for g in report.plan.gears],
        [tuple(g.cascade.thresholds) for g in report.plan.gears],
        [tuple(sorted(g.min_queue_lens.items()))
         for g in report.plan.gears],
        {m: sorted(d.items()) for g in report.plan.gears
         for m, d in g.load_fractions.items()},
        [(r.model, r.device) for r in report.plan.replicas],
        [g.expected_p95 for g in report.plan.gears],
        [g.expected_accuracy for g in report.plan.gears],
    )


def test_plan_parity_latency_slo(bert_like_profiles, small_plan):
    """The standing latency-SLO planner scenario (same as the small_plan
    fixture): the fast path's final GearPlan — assignments, triggers,
    placement, even the DES-certified p95s — is identical to the
    pre-change planner's."""
    fast_report, hw = small_plan
    legacy = optimize_gear_plan(
        bert_like_profiles, hw, SLO(kind="latency", latency_p95=0.4),
        qps_max=7600, n_ranges=8, fast_path=False)
    assert plan_signature(legacy) == plan_signature(fast_report)


def test_plan_parity_accuracy_slo(bert_like_profiles):
    hw = HardwareSpec(num_devices=4, mem_per_device=16e9)
    slo = SLO(kind="accuracy", min_accuracy=0.93)
    legacy = optimize_gear_plan(bert_like_profiles, hw, slo, qps_max=5000,
                                n_ranges=8, fast_path=False)
    fast = optimize_gear_plan(bert_like_profiles, hw, slo, qps_max=5000,
                              n_ranges=8, fast_path=True)
    assert plan_signature(legacy) == plan_signature(fast)


def test_plan_parity_overhead_regime(bert_like_profiles):
    """Deep trigger ladders (calibrated dispatch overhead makes small
    batches genuinely unstable): the regime the fast sweep accelerates
    most still converges to the pre-change plan."""
    hw = HardwareSpec(num_devices=4, mem_per_device=16e9)
    slo = SLO(kind="latency", latency_p95=0.5)
    cfg = SimConfig(dispatch_overhead=2e-3)
    legacy = optimize_gear_plan(bert_like_profiles, hw, slo, qps_max=5000,
                                n_ranges=6, sim_cfg=cfg, fast_path=False)
    fast = optimize_gear_plan(bert_like_profiles, hw, slo, qps_max=5000,
                              n_ranges=6, sim_cfg=cfg, fast_path=True)
    assert plan_signature(legacy) == plan_signature(fast)
    assert any(max(g.min_queue_lens.values()) > 1
               for g in legacy.plan.gears), \
        "scenario no longer exercises trigger growth"


def test_warm_replan_reuses_memo(bert_like_profiles):
    """A steady-state re-plan (drifted prior, pinned placement, chained
    warm state) must run on memoized DES outcomes: zero new simulations,
    identical plan to the legacy warm re-plan."""
    hw = HardwareSpec(num_devices=4, mem_per_device=16e9)
    slo = SLO(kind="latency", latency_p95=0.4)
    cold = optimize_gear_plan(bert_like_profiles, hw, slo, qps_max=5000,
                              n_ranges=6, fast_path=True)
    prior = np.linspace(1.0, 2.0, 6)
    prior /= prior.sum()
    w1 = optimize_gear_plan(bert_like_profiles, hw, slo, qps_max=5000,
                            n_ranges=6, qps_prior=prior,
                            pinned_replicas=list(cold.plan.replicas),
                            warm_state=cold.state, fast_path=True)
    prior2 = np.linspace(1.0, 3.0, 6)
    prior2 /= prior2.sum()
    w2 = optimize_gear_plan(bert_like_profiles, hw, slo, qps_max=5000,
                            n_ranges=6, qps_prior=prior2,
                            pinned_replicas=list(cold.plan.replicas),
                            warm_state=w1.state, fast_path=True)
    assert w2.state.sim_memo.misses == 0, \
        "steady-state re-plan ran fresh simulations despite the memo"
    legacy = optimize_gear_plan(bert_like_profiles, hw, slo, qps_max=5000,
                                n_ranges=6, qps_prior=prior2,
                                pinned_replicas=list(cold.plan.replicas),
                                warm_state=w1.state, fast_path=False)
    assert plan_signature(legacy) == plan_signature(w2)


def test_report_submodule_seconds(small_plan):
    report, _ = small_plan
    breakdown = report.submodule_seconds
    assert set(breakdown) >= {"SP1:search_cascades", "SP2:assign_cascades",
                              "SP3:place_models", "SP4:tune_batch_sizes"}
    assert all(s >= 0 for s in breakdown.values())
    assert sum(breakdown.values()) <= report.wall_seconds + 1e-6
