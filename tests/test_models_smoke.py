"""Per-architecture smoke tests (assignment requirement): reduced config,
one forward/train step on CPU, output shapes + no NaNs; plus prefill/decode
consistency against the full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import model as M


def make_batch(cfg, b=2, s=24, with_labels=True, rng=0):
    key = jax.random.PRNGKey(rng)
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if with_labels:
        batch["labels"] = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    if cfg.frontend.kind == "vision":
        batch["prefix_embeddings"] = jnp.ones(
            (b, cfg.frontend.num_prefix_embeddings,
             cfg.frontend.frontend_dim), jnp.bfloat16)
    if cfg.is_encoder_decoder:
        batch["source_frames"] = jax.random.normal(
            key, (b, 16, cfg.frontend.frontend_dim or cfg.d_model)
        ).astype(jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    logits, aux = M.forward(params, cfg, batch)
    s_tot = 24 + (cfg.frontend.num_prefix_embeddings
                  if cfg.frontend.kind == "vision" else 0)
    assert logits.shape == (2, s_tot, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    loss, metrics = M.train_loss(params, cfg, batch, remat=True)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    grads = jax.grad(lambda p: M.train_loss(p, cfg, batch, remat=True)[0]
                     )(params)
    gleaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g.astype(jnp.float32))))
               for g in gleaves)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_shapes(arch):
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 16
    batch = make_batch(cfg, b, s, with_labels=False)
    # the cache must cover the FULL prompt incl. any modality prefix
    # (prefill validates this since the cache_len sentinel fix)
    s_tot = s + (cfg.frontend.num_prefix_embeddings
                 if cfg.frontend.kind == "vision" else 0)
    logits, cache = M.prefill(params, cfg, batch, cache_len=s_tot + 4)
    assert logits.shape == (b, cfg.vocab_size)
    tok = jnp.zeros((b, 1), jnp.int32)
    dlogits, cache2 = M.decode_step(params, cfg, tok, cache,
                                    jnp.asarray(s_tot, jnp.int32))
    assert dlogits.shape == (b, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(dlogits)))
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "h2o-danube-1.8b",
                                  "falcon-mamba-7b", "seamless-m4t-large-v2",
                                  "olmo-1b", "qwen3-32b"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode equals the full forward (exact for non-MoE)."""
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    b, s = 2, 13
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s + 1), 0,
                              cfg.vocab_size)
    full = make_batch(cfg, b, s + 1, with_labels=False, rng=3)
    full["tokens"] = toks
    pre = dict(full)
    pre["tokens"] = toks[:, :s]
    logits_full, _ = M.forward(params, cfg, full)
    logits_pre, cache = M.prefill(params, cfg, pre, cache_len=s + 1)
    np.testing.assert_allclose(np.asarray(logits_pre),
                               np.asarray(logits_full[:, s - 1]),
                               atol=2e-2, rtol=0)
    dl, _ = M.decode_step(params, cfg, toks[:, s:s + 1], cache,
                          jnp.asarray(s, jnp.int32))
    np.testing.assert_allclose(np.asarray(dl),
                               np.asarray(logits_full[:, s]),
                               atol=5e-2, rtol=0)


def test_sliding_window_ring_buffer():
    """Danube's SWA ring cache: decode past the window matches forward."""
    cfg = get_smoke_config("h2o-danube-1.8b")
    assert cfg.sliding_window == 64
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    b, s = 1, 80  # past the 64-token window
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s + 1), 0,
                              cfg.vocab_size)
    logits_full, _ = M.forward(params, cfg, {"tokens": toks})
    _, cache = M.prefill(params, cfg, {"tokens": toks[:, :s]},
                         cache_len=s + 1)
    assert cache["blocks"][0]["k"].shape[2] == cfg.sliding_window
    dl, _ = M.decode_step(params, cfg, toks[:, s:s + 1], cache,
                          jnp.asarray(s, jnp.int32))
    np.testing.assert_allclose(np.asarray(dl), np.asarray(logits_full[:, s]),
                               atol=5e-2, rtol=0)


def test_block_pattern_structure():
    from repro.models.model import block_pattern
    from repro.configs import get_config
    jamba = block_pattern(get_config("jamba-v0.1-52b"))
    assert len(jamba) == 8
    assert [sp.mixer for sp in jamba].count("attn") == 1
    assert jamba[4].mixer == "attn"
    assert [sp.ffn for sp in jamba].count("moe") == 4
    llama4 = block_pattern(get_config("llama4-maverick-400b-a17b"))
    assert [sp.ffn for sp in llama4] == ["dense", "moe"]
