"""Assigned-architecture configs: exact assignment numbers + derived counts."""
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.configs.shapes import SHAPES, cell_is_applicable, skip_reason

ASSIGNED = {
    # arch: (layers, d_model, heads, kv, d_ff, vocab)
    "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
    "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
    "falcon-mamba-7b": (64, 4096, 1, 1, 0, 65024),
    "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
    "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
    "qwen3-32b": (64, 5120, 64, 8, 25600, 151936),
    "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32000),
    "qwen2-0.5b": (24, 896, 14, 2, 4864, 151936),
    "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
    "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
}


def test_all_archs_registered():
    assert set(ARCH_IDS) == set(ASSIGNED)


@pytest.mark.parametrize("arch", list(ASSIGNED))
def test_assignment_numbers(arch):
    cfg = get_config(arch)
    l, d, h, kv, ff, v = ASSIGNED[arch]
    assert cfg.num_layers == l
    assert cfg.d_model == d
    assert cfg.num_heads == h
    assert cfg.num_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab_size == v


def test_moe_configs():
    llama4 = get_config("llama4-maverick-400b-a17b").moe
    assert llama4.num_experts == 128 and llama4.top_k == 1
    qmoe = get_config("qwen2-moe-a2.7b").moe
    assert qmoe.num_experts == 60 and qmoe.top_k == 4
    assert qmoe.num_shared_experts == 4
    jamba = get_config("jamba-v0.1-52b").moe
    assert jamba.num_experts == 16 and jamba.top_k == 2


def test_param_counts_match_names():
    """Total/active param counts should land near the published sizes."""
    def total_b(arch):
        return get_config(arch).param_count() / 1e9

    def active_b(arch):
        return get_config(arch).active_param_count() / 1e9

    assert 350 < total_b("llama4-maverick-400b-a17b") < 450
    assert 12 < active_b("llama4-maverick-400b-a17b") < 22
    assert 25 < total_b("qwen3-32b") < 40
    assert 5.5 < total_b("falcon-mamba-7b") < 9
    assert 40 < total_b("jamba-v0.1-52b") < 65
    assert 9 < active_b("jamba-v0.1-52b") < 16
    assert 10 < total_b("qwen2-moe-a2.7b") < 18
    assert 2 < active_b("qwen2-moe-a2.7b") < 4.5
    assert 0.4 < total_b("qwen2-0.5b") < 0.8
    assert 1.1 < total_b("olmo-1b") < 1.6
    assert 1.4 < total_b("h2o-danube-1.8b") < 2.2


def test_hybrid_pattern():
    cfg = get_config("jamba-v0.1-52b")
    attn_layers = [i for i in range(cfg.num_layers)
                   if cfg.layer_is_attention(i)]
    assert len(attn_layers) == 4  # 1:7 interleave over 32 layers
    moe_layers = [i for i in range(cfg.num_layers) if cfg.layer_is_moe(i)]
    assert len(moe_layers) == 16  # every other layer


def test_shape_cell_accounting():
    """40 cells = 33 runnable + 7 documented long_500k skips."""
    runnable, skips = 0, 0
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            if cell_is_applicable(cfg, shape):
                runnable += 1
            else:
                skips += 1
                assert skip_reason(cfg, shape)
                assert shape.name == "long_500k"
    assert runnable == 33 and skips == 7


def test_long_context_rules():
    assert get_config("falcon-mamba-7b").supports_long_context
    assert get_config("jamba-v0.1-52b").supports_long_context
    assert get_config("h2o-danube-1.8b").supports_long_context  # SWA
    assert not get_config("qwen3-32b").supports_long_context
    assert not get_config("llama4-maverick-400b-a17b").supports_long_context


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_configs_reduced(arch):
    cfg = get_smoke_config(arch)
    assert cfg.d_model <= 128
    assert cfg.vocab_size <= 512
    assert cfg.num_layers <= 8
    assert cfg.family == get_config(arch).family
