"""Multi-device integration (subprocess: XLA device-count flag must be set
before jax initialises, which the main pytest process has already done)."""
import os
import subprocess
import sys

import pytest

jax = pytest.importorskip("jax")

# These paths target the jax >= 0.5 shard_map surface; on 0.4.x the
# repro.distributed.compat shim translates them (fully-manual fallback;
# compress_pod_grads degrades to the uncompressed pod all-reduce with a
# RuntimeWarning), so the integration runs on either version.

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec
from repro.configs import get_smoke_config
from repro.models import model as M
from repro.launch.mesh import make_mesh, context_for_mesh
from repro.distributed.context import use_context
from repro.distributed import sharding as sh
from repro.training import (AdamWConfig, make_train_step, TrainStepConfig,
                            init_opt_state, opt_state_pspecs, SyntheticDataset)

# 1) EP MoE parity: sharded loss == local loss (within capacity/bf16 noise)
cfg = get_smoke_config("qwen2-moe-a2.7b")
mesh = make_mesh((4, 2), ("data", "model"))
ctx = context_for_mesh(mesh)
params = M.init_params(cfg, jax.random.PRNGKey(0))
batch = {"tokens": jnp.zeros((8, 16), jnp.int32) + 3,
         "labels": jnp.ones((8, 16), jnp.int32)}
loss_ref, _ = M.train_loss(params, cfg, batch, remat=False)
pspecs = sh.param_shardings(params, ctx, mode="train")
params_sh = jax.device_put(params, pspecs)
with use_context(ctx):
    loss_sh = jax.jit(lambda p, b: M.train_loss(p, cfg, b, remat=False)[0])(
        params_sh, batch)
delta = abs(float(loss_ref) - float(loss_sh))
assert delta < 2e-2, f"EP parity delta {delta}"
print("EP_PARITY_OK", delta)

# 2) multi-pod train step with int8 pod-compressed grads + ZeRO-1
cfg2 = get_smoke_config("olmo-1b")
mesh3 = make_mesh((2, 2, 2), ("pod", "data", "model"))
ctx3 = context_for_mesh(mesh3)
p2 = M.init_params(cfg2, jax.random.PRNGKey(0))
pspecs2 = sh.param_pspecs(p2, ctx3, mode="train")
p2 = jax.device_put(p2, jax.tree.map(
    lambda s: NamedSharding(mesh3, s), pspecs2,
    is_leaf=lambda s: isinstance(s, PartitionSpec)))
opt2 = init_opt_state(p2)
ospecs = opt_state_pspecs(pspecs2, zero1_axis="pod")
opt2 = jax.device_put(opt2, jax.tree.map(
    lambda s: NamedSharding(mesh3, s), ospecs,
    is_leaf=lambda s: isinstance(s, PartitionSpec)))
ds = SyntheticDataset(cfg2, batch=8, seq_len=32, seed=0)
step = make_train_step(cfg2, AdamWConfig(learning_rate=1e-3, warmup_steps=2,
                                         decay_steps=50),
                       TrainStepConfig(remat=True, compress_pod_grads=True))
losses = []
with use_context(ctx3):
    jitted = jax.jit(step)
    for _ in range(6):
        b = {k: jnp.asarray(v) for k, v in ds.next_batch().items()}
        p2, opt2, m = jitted(p2, opt2, b)
        losses.append(float(m["loss"]))
assert losses[-1] < losses[0], losses
print("MULTIPOD_TRAIN_OK", losses[0], "->", losses[-1])

# 3) ZeRO-1: moments really are sharded over the pod axis
mspec = jax.tree.leaves(opt2["m"])[1].sharding.spec
assert any("pod" == a or (isinstance(a, tuple) and "pod" in a)
           for a in mspec if a is not None), mspec
print("ZERO1_SHARDING_OK")

# 4) sharded flash-decoding == dense decode (EXPERIMENTS.md Perf H2)
cfg4 = get_smoke_config("qwen3-32b")
p4 = M.init_params(cfg4, jax.random.PRNGKey(1), dtype=jnp.float32)
toks = jax.random.randint(jax.random.PRNGKey(2), (4, 17), 0, cfg4.vocab_size)
_, cache_ref = M.prefill(p4, cfg4, {"tokens": toks[:, :16]}, cache_len=17)
ref, _ = M.decode_step(p4, cfg4, toks[:, 16:17], cache_ref,
                       jnp.asarray(16, jnp.int32))
mesh4 = make_mesh((2, 4), ("data", "model"))
ctx4 = context_for_mesh(mesh4, flash_decode=True)
_, cache20 = M.prefill(p4, cfg4, {"tokens": toks[:, :16]}, cache_len=20)
with use_context(ctx4):
    out, _ = jax.jit(lambda p, c, t, i: M.decode_step(p, cfg4, t, c, i))(
        p4, cache20, toks[:, 16:17], jnp.asarray(16, jnp.int32))
err = float(jnp.max(jnp.abs(out - ref)))
assert err < 1e-4, f"flash decode err {err}"
print("FLASH_DECODE_OK", err)

# 5) sequence-parallel attention parity (Perf H3; 14 heads, 4-way model)
cfg5 = get_smoke_config("qwen2-0.5b")  # 4 smoke heads; force non-tiling
import dataclasses
cfg5 = dataclasses.replace(cfg5, num_heads=6, num_kv_heads=2, head_dim=32,
                           d_model=192)
p5 = M.init_params(cfg5, jax.random.PRNGKey(3), dtype=jnp.float32)
batch5 = {"tokens": jax.random.randint(jax.random.PRNGKey(4), (4, 24), 0,
                                       cfg5.vocab_size)}
ref5, _ = M.forward(p5, cfg5, batch5)
with use_context(context_for_mesh(mesh4)):
    out5, _ = jax.jit(lambda p, b: M.forward(p, cfg5, b))(p5, batch5)
err5 = float(jnp.max(jnp.abs(out5 - ref5)))
assert err5 < 1e-3, f"seq-parallel err {err5}"
print("SEQ_PARALLEL_OK", err5)
"""


@pytest.mark.slow
def test_multidevice_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    res = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert res.returncode == 0, res.stderr[-3000:]
    assert "EP_PARITY_OK" in res.stdout
    assert "MULTIPOD_TRAIN_OK" in res.stdout
    assert "ZERO1_SHARDING_OK" in res.stdout
    assert "FLASH_DECODE_OK" in res.stdout
    assert "SEQ_PARALLEL_OK" in res.stdout


@pytest.mark.slow
def test_dryrun_cell_subprocess():
    """One production-mesh dry-run cell end to end (512 fake devices)."""
    script = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = "
        "'--xla_force_host_platform_device_count=512'\n"
        "from repro.launch.dryrun import run_cell\n"
        "row = run_cell('olmo-1b', 'decode_32k', 'single')\n"
        "assert row['status'] == 'ok', row.get('error')\n"
        "assert row['hlo_flops'] > 0\n"
        "row2 = run_cell('olmo-1b', 'decode_32k', 'multi')\n"
        "assert row2['status'] == 'ok', row2.get('error')\n"
        "print('DRYRUN_OK', row['dominant'], row2['chips'])\n")
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    res = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert res.returncode == 0, res.stderr[-3000:]
    assert "DRYRUN_OK" in res.stdout
