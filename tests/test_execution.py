"""The ExecutionBackend layer (core/execution.py): the three backends, the
unified profile entry point, engine bucketing/padding correctness, and the
constructor validation that replaced bare asserts."""
import numpy as np
import pytest

from repro.core.cascade import Cascade
from repro.core.execution import (BatchExecution, CostModelBackend,
                                  EngineBackend, ReplayBackend,
                                  profile_backend, resolve_estimator)
from repro.core.gears import GearPlan, SLO
from repro.core.lp import Replica
from repro.core.profiles import (ModelProfile, ValidationRecord,
                                 synthetic_family)
from repro.core.simulator import ServingSimulator, SimConfig, make_gear


# ---------------------------------------------------------------------------
# ReplayBackend
# ---------------------------------------------------------------------------

def test_replay_backend_replays_validation(bert_like_profiles):
    b = ReplayBackend(bert_like_profiles)
    rec = bert_like_profiles["tiny"].validation
    n = len(rec.certs)
    sids = [0, 3, n + 3, 2 * n]      # wraps around the validation set
    ex = b.execute("tiny", sids)
    assert list(ex.certs) == [rec.certs[s % n] for s in sids]
    assert list(ex.correct) == [bool(rec.correct[s % n]) for s in sids]
    assert ex.elapsed is None        # virtual physics: no wall time spent
    # runtimes come from profile interpolation
    assert b.batch_runtime("tiny", 4) == \
        bert_like_profiles["tiny"].runtime(4)


def test_simulator_identical_through_explicit_replay_backend(
        bert_like_profiles):
    """Default backend vs explicitly passed ReplayBackend: the refactor
    contract is that the driver never special-cases the source, so both
    must produce the bit-identical SimResult."""
    profiles = bert_like_profiles
    reps = [Replica(m, d, profiles[m].runtime_per_sample(1.0))
            for d in range(2) for m in ("tiny", "base")]
    g = make_gear(Cascade(("tiny", "base"), (0.35,)), reps, {"tiny": 2})
    plan = GearPlan(qps_max=400.0, gears=[g], replicas=reps, num_devices=2,
                    slo=SLO(kind="latency", latency_p95=1.0))
    trace = np.concatenate([np.full(3, 60.0), np.full(3, 300.0)])
    r1 = ServingSimulator(profiles, reps, 2, SimConfig(max_batch=128)) \
        .run_trace(plan, trace)
    r2 = ServingSimulator(profiles, reps, 2, SimConfig(max_batch=128),
                          backend=ReplayBackend(profiles)) \
        .run_trace(plan, trace)
    assert r1.completed == r2.completed
    assert np.array_equal(r1.latencies, r2.latencies)
    assert np.array_equal(r1.correct, r2.correct)
    assert np.array_equal(r1.resolver, r2.resolver)


def test_replay_backend_profile_is_the_stored_artifact(bert_like_profiles):
    b = ReplayBackend(bert_like_profiles)
    assert profile_backend(b, "tiny") is bert_like_profiles["tiny"]
    # resampling onto a new grid uses the same runtime interpolation
    p = profile_backend(b, "tiny", batch_sizes=(3, 5))
    assert p.batch_runtimes[0] == bert_like_profiles["tiny"].runtime(3)
    # the set form covers every model the backend serves
    ps = profile_backend(b)
    assert set(ps) == set(bert_like_profiles)


# ---------------------------------------------------------------------------
# EngineBackend
# ---------------------------------------------------------------------------

class _RowEngine:
    """Fake engine whose scores encode the input rows, so padding leaks and
    row misalignment are detectable: scores[i] = (tokens[i,0], -1)."""

    def __init__(self):
        self.batch_sizes = []

    def infer(self, tokens):
        tokens = np.asarray(tokens)
        self.batch_sizes.append(tokens.shape[0])
        out = np.full((tokens.shape[0], 2), -1.0)
        out[:, 0] = tokens[:, 0]
        return out


def test_engine_backend_matches_engine_plus_estimator():
    eng = _RowEngine()
    b = EngineBackend({"m": eng}, estimator=lambda s: s[:, 0] - s[:, 1])
    toks = [np.array([7, 0]), np.array([2, 0])]
    ex = b.execute("m", [0, 1], tokens=toks)
    assert list(ex.preds) == [0, 0]
    assert list(ex.certs) == [8.0, 3.0]     # (7 - -1), (2 - -1)
    assert ex.correct is None               # no labels attached
    assert ex.elapsed is not None and ex.elapsed >= 0.0


def test_engine_backend_token_and_label_pools():
    """With sid-indexed pools the backend executes from sample ids alone
    (what lets the DES drive real models) and reports correctness."""
    pool = np.arange(6, dtype=np.int64).reshape(3, 2) * 10
    labels = np.array([0, 1, 0])
    b = EngineBackend({"m": _RowEngine()}, estimator=lambda s: s[:, 0],
                      tokens=pool, labels=labels)
    ex = b.execute("m", [1, 3])             # 3 wraps to pool row 0
    assert list(ex.certs) == [20.0, 0.0]
    # preds are always 0 (scores[:,0] >= scores[:,1]) -> correct vs labels
    assert ex.correct == [False, True]
    # caller-supplied tokens are NOT the pool's: pairing their predictions
    # with pool labels would be noise, so correctness must be unknown
    ex2 = b.execute("m", [1, 3], tokens=[np.array([5, 0]),
                                         np.array([6, 0])])
    assert ex2.correct is None
    with pytest.raises(RuntimeError):
        EngineBackend({"m": _RowEngine()}).execute("m", [0])  # no pool


def test_simulator_unknown_correctness_reads_nan(bert_like_profiles):
    """Real models in the DES without a label pool: latency metrics are
    valid, but accuracy must read UNKNOWN (nan), never silently 0.0."""
    import math
    profiles = bert_like_profiles
    reps = [Replica("tiny", 0, profiles["tiny"].runtime_per_sample(1.0))]
    g = make_gear(Cascade(("tiny",), ()), reps)
    plan = GearPlan(qps_max=200.0, gears=[g], replicas=reps, num_devices=1,
                    slo=SLO(kind="latency", latency_p95=1.0))
    pool = np.zeros((8, 2), np.int64)
    b = EngineBackend({"tiny": _RowEngine()}, estimator=lambda s: s[:, 0],
                      tokens=pool, profiles=profiles)   # tokens, NO labels
    sim = ServingSimulator(profiles, reps, 1, backend=b)
    r = sim.run_trace(plan, np.full(2, 30.0))
    assert r.completed == r.offered > 0
    assert not r.correctness_known
    assert math.isnan(r.accuracy)
    # the default replay physics still knows correctness
    r2 = ServingSimulator(profiles, reps, 1).run_trace(plan,
                                                       np.full(2, 30.0))
    assert r2.correctness_known and not math.isnan(r2.accuracy)


def test_engine_backend_requires_profiles_for_virtual_time():
    b = EngineBackend({"m": _RowEngine()})
    with pytest.raises(RuntimeError):
        b.batch_runtime("m", 4)
    prof = ModelProfile(name="m", mem_bytes=1.0,
                        batch_sizes=np.array([1.0, 8.0]),
                        batch_runtimes=np.array([1e-3, 4e-3]),
                        validation=ValidationRecord(
                            certs=np.zeros(4), correct=np.ones(4, bool)))
    b2 = EngineBackend({"m": _RowEngine()}, profiles={"m": prof})
    assert b2.batch_runtime("m", 8) == pytest.approx(4e-3)


# ---------------------------------------------------------------------------
# InferenceEngine bucketing / padding / profiling (satellite coverage)
# ---------------------------------------------------------------------------

def test_engine_padding_does_not_leak_into_scores():
    """Padded rows must neither appear in the returned scores nor displace
    the real rows: row i of the output must correspond to input row i."""
    from repro.serving.engine import InferenceEngine
    import jax.numpy as jnp
    seen = []

    def apply_fn(params, tokens):
        seen.append(int(tokens.shape[0]))
        out = jnp.stack([tokens[:, 0].astype(jnp.float32),
                         jnp.full((tokens.shape[0],), -1.0)], axis=-1)
        return out

    eng = InferenceEngine("x", apply_fn, {}, buckets=(1, 2, 4, 8))
    toks = np.arange(3, dtype=np.int32)[:, None] + 5   # rows 5, 6, 7
    out = eng.infer(np.repeat(toks, 4, axis=1))
    assert seen[-1] == 4                   # padded up to the 4-bucket
    assert out.shape == (3, 2)             # pad rows sliced away
    assert out[:, 0].tolist() == [5.0, 6.0, 7.0]   # alignment preserved


def test_engine_oversized_batch_split_preserves_rows():
    from repro.serving.engine import InferenceEngine
    import jax.numpy as jnp

    def apply_fn(params, tokens):
        return jnp.stack([tokens[:, 0].astype(jnp.float32),
                          jnp.zeros((tokens.shape[0],))], axis=-1)

    eng = InferenceEngine("x", apply_fn, {}, buckets=(1, 2, 4, 8))
    n = 13                                  # 8 + 5(->8 bucket)
    toks = np.arange(n, dtype=np.int32)[:, None].repeat(2, axis=1)
    out = eng.infer(toks)
    assert out.shape == (n, 2)
    assert out[:, 0].tolist() == list(range(n))


def test_profile_engine_positive_sorted_runtimes():
    from repro.serving.engine import InferenceEngine, profile_engine
    import jax.numpy as jnp

    def apply_fn(params, tokens):
        return jnp.zeros((tokens.shape[0], 2))

    eng = InferenceEngine("x", apply_fn, {}, buckets=(1, 2, 4, 8))
    p = profile_engine(eng, seq_len=4, batch_sizes=(4, 1, 8), repeats=2)
    assert np.all(p.batch_runtimes > 0.0)
    # profile normalises onto an ascending batch-size grid
    assert p.batch_sizes.tolist() == [1.0, 4.0, 8.0]
    assert p.name == "x"


# ---------------------------------------------------------------------------
# CostModelBackend
# ---------------------------------------------------------------------------

def test_cost_model_backend_matches_analytic_profile():
    from repro.configs import get_config
    from repro.profiling.cost_model import profile_from_cost_model
    arch = "qwen2-0.5b"
    b = CostModelBackend({arch: arch}, context=512,
                         batch_sizes=(1, 4, 16))
    direct = profile_from_cost_model(get_config(arch), context=512,
                                     kind="decode", batch_sizes=(1, 4, 16))
    p = profile_backend(b, arch)
    assert np.allclose(p.batch_runtimes, direct.batch_runtimes)
    assert p.devices_per_replica == direct.devices_per_replica
    assert b.batch_runtime(arch, 4) == pytest.approx(direct.runtime(4))
    # and it replays like any other backend (synthetic default validation)
    ex = b.execute(arch, [0, 1])
    assert len(ex.certs) == 2


def test_cost_model_backend_carries_validation_structure():
    synth = synthetic_family(["a"], seed=7, n_val=64)
    b = CostModelBackend({"a": "qwen2-0.5b"},
                         validation={"a": synth["a"].validation},
                         batch_sizes=(1, 4))
    assert b.validation_record("a") is synth["a"].validation
    ex = b.execute("a", list(range(5)))
    assert list(ex.certs) == synth["a"].validation.certs[:5].tolist()


# ---------------------------------------------------------------------------
# resolve_estimator (single home of the estimator lookup)
# ---------------------------------------------------------------------------

def test_resolve_estimator():
    fn = resolve_estimator("top2_gap")
    scores = np.array([[3.0, 1.0, 0.5]])
    assert float(np.asarray(fn(scores))[0]) == pytest.approx(2.0)
    marker = lambda s: s                       # noqa: E731
    assert resolve_estimator(marker) is marker  # callables pass through
    with pytest.raises(ValueError):
        resolve_estimator("nope")


# ---------------------------------------------------------------------------
# Constructor validation (explicit ValueErrors, not bare asserts)
# ---------------------------------------------------------------------------

def test_cascade_validation_raises_value_error():
    with pytest.raises(ValueError):
        Cascade(("a", "b"), ())                # missing threshold
    with pytest.raises(ValueError):
        Cascade((), ())                        # no models


def test_validation_record_raises_value_error():
    with pytest.raises(ValueError):
        ValidationRecord(certs=np.zeros(3), correct=np.ones(2, bool))
    with pytest.raises(ValueError):
        ValidationRecord(certs=np.zeros(0), correct=np.zeros(0, bool))
    with pytest.raises(ValueError):
        ValidationRecord(certs=np.zeros(3), correct=np.ones(3, bool),
                         preds=np.zeros(2, np.int64))


def test_model_profile_raises_value_error():
    rec = ValidationRecord(certs=np.zeros(2), correct=np.ones(2, bool))
    with pytest.raises(ValueError):
        ModelProfile(name="m", mem_bytes=1.0,
                     batch_sizes=np.array([1.0, 2.0]),
                     batch_runtimes=np.array([1e-3]), validation=rec)
    with pytest.raises(ValueError):
        ModelProfile(name="m", mem_bytes=1.0, batch_sizes=np.array([]),
                     batch_runtimes=np.array([]), validation=rec)
    with pytest.raises(ValueError):
        ModelProfile(name="m", mem_bytes=1.0, batch_sizes=np.array([0.0]),
                     batch_runtimes=np.array([1e-3]), validation=rec)
    with pytest.raises(ValueError):
        ModelProfile(name="m", mem_bytes=1.0, batch_sizes=np.array([1.0]),
                     batch_runtimes=np.array([-1e-3]), validation=rec)
    with pytest.raises(ValueError):
        ModelProfile(name="m", mem_bytes=1.0, batch_sizes=np.array([1.0]),
                     batch_runtimes=np.array([np.inf]), validation=rec)


# ---------------------------------------------------------------------------
# Cross-driver: the wall-clock server on replayed physics, and the
# virtual-time server defaulting to its backend's runtime model
# ---------------------------------------------------------------------------

def test_threaded_server_serves_replay_backend(bert_like_profiles):
    """ReplayBackend behind the REAL threaded machinery: compute-free
    serving (the high-QPS stress configuration)."""
    import time as _time
    from repro.serving.runtime import CascadeServer, Request
    profiles = bert_like_profiles
    reps = [Replica("tiny", 0, profiles["tiny"].runtime_per_sample(1.0))]
    g = make_gear(Cascade(("tiny",), ()), reps)
    plan = GearPlan(qps_max=500.0, gears=[g], replicas=reps, num_devices=1,
                    slo=SLO(kind="latency", latency_p95=1.0))
    server = CascadeServer(plan, backend=ReplayBackend(profiles))
    server.start()
    for i in range(32):
        server.submit(Request(rid=i, tokens=np.zeros(1, np.int32)))
    deadline = _time.monotonic() + 5.0
    while len(server.completed) < 32 and _time.monotonic() < deadline:
        _time.sleep(0.01)
    server.stop()
    assert len(server.completed) == 32
    rec = profiles["tiny"].validation
    done = sorted(server.completed, key=lambda r: r.rid)
    n = len(rec.certs)
    assert [r.cert for r in done] == \
        [rec.certs[r.rid % n] for r in done]


def test_run_virtual_defaults_to_backend_runtime(bert_like_profiles):
    """run_virtual without an explicit batch_runtime uses the backend's
    own runtime model — same results as passing the profile lookup."""
    from repro.serving.runtime import CascadeServer, Request
    profiles = bert_like_profiles
    reps = [Replica(m, d, profiles[m].runtime_per_sample(1.0))
            for d in range(2) for m in ("tiny", "base")]
    g = make_gear(Cascade(("tiny", "base"), (0.35,)), reps, {"tiny": 2})
    plan = GearPlan(qps_max=400.0, gears=[g], replicas=reps, num_devices=2,
                    slo=SLO(kind="latency", latency_p95=1.0))
    trace = np.full(3, 80.0)

    def run(**kw):
        server = CascadeServer(plan, backend=ReplayBackend(profiles))
        n = int(trace.sum()) + 4
        reqs = [Request(rid=i, tokens=np.zeros(1, np.int32))
                for i in range(n)]
        return server.run_virtual(reqs, trace, **kw)

    implicit = run()
    explicit = run(batch_runtime=lambda m, b: profiles[m].runtime(b))
    assert len(implicit) == len(explicit) > 0
    assert [r.t_done for r in implicit] == [r.t_done for r in explicit]
