"""Roofline extraction: loop-aware HLO cost model exactness + report math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.profiling import hw
from repro.profiling.hlo_cost import analyze_hlo_text, parse_hlo
from repro.profiling.roofline import (RooflineReport,
                                      collective_bytes_from_hlo)


def test_matmul_flops_exact():
    def mm(a, b):
        return a @ b
    a = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 128), jnp.float32)
    c = jax.jit(mm).lower(a, b).compile()
    s = analyze_hlo_text(c.as_text())
    assert s.flops == pytest.approx(2 * 256 * 512 * 128, rel=0.01)


@pytest.mark.skipif(
    tuple(int(x) for x in jax.__version__.split(".")[:2]) < (0, 5),
    reason="Compiled.cost_analysis returns a per-device LIST on jax < 0.5; "
           "the dict comparison below needs the new structure")
def test_scan_loop_trip_count_multiplies():
    """THE bug this module exists for: XLA cost_analysis counts while
    bodies once; ours multiplies by the derived trip count."""
    def scanned(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=8)
        return h
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = jax.jit(scanned).lower(x, w).compile()
    xla = c.cost_analysis().get("flops", 0.0)
    ours = analyze_hlo_text(c.as_text()).flops
    true = 8 * 2 * 128 ** 3
    assert ours == pytest.approx(true, rel=0.01)
    assert xla < true / 4  # XLA undercounts (counts the body once)


def test_nested_scan():
    def nested(x, w):
        def outer(h, _):
            def inner(g, _):
                return g @ w, None
            g, _ = jax.lax.scan(inner, h, None, length=4)
            return g, None
        h, _ = jax.lax.scan(outer, x, None, length=3)
        return h
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = jax.jit(nested).lower(x, w).compile()
    s = analyze_hlo_text(c.as_text())
    assert s.flops == pytest.approx(12 * 2 * 64 ** 3, rel=0.01)


def test_collective_parser_synthetic_text():
    text = """
HloModule m

ENTRY %main (a: f32[1024]) -> f32[2048] {
  %a = f32[1024]{0} parameter(0)
  %ag = f32[2048]{0} all-gather(%a), replica_groups=[8,2]<=[16], dimensions={0}
  %ar = f32[2048]{0} all-reduce(%ag), replica_groups=[4,4]<=[16], to_apply=%add
  ROOT %rs = f32[1024]{0} reduce-scatter(%ar), replica_groups=[8,2]<=[16], dimensions={0}
}
"""
    out = collective_bytes_from_hlo(text)
    assert out["all-gather"] == 2048 * 4 // 2      # result / group
    assert out["all-reduce"] == 2048 * 4            # == result
    assert out["reduce-scatter"] == 1024 * 4 * 2    # result x group


def test_roofline_report_math():
    rep = RooflineReport(
        arch="x", shape="train_4k", mesh="single", chips=256,
        hlo_flops=1e12, hlo_bytes=1e10, collective_bytes=1e9,
        collective_breakdown={}, model_flops_total=200e12,
        model_bytes_total=1e12)
    assert rep.t_compute == pytest.approx(1e12 / hw.PEAK_FLOPS_BF16)
    assert rep.t_memory == pytest.approx(1e10 / hw.HBM_BW)
    assert rep.t_collective == pytest.approx(1e9 / hw.ICI_BW)
    assert rep.dominant == "collective"
    d = rep.to_dict()
    assert 0 < d["roofline_fraction"] <= 1.0 or d["roofline_fraction"] > 0


def test_parse_hlo_computations():
    text = """
HloModule m

%helper (p: f32[4]) -> f32[4] {
  %p = f32[4]{0} parameter(0)
  ROOT %t = f32[4]{0} tanh(%p)
}

ENTRY %main (x: f32[4]) -> f32[4] {
  %x = f32[4]{0} parameter(0)
  ROOT %c = f32[4]{0} call(%x), to_apply=%helper
}
"""
    comps, entry = parse_hlo(text)
    assert entry == "main"
    assert "helper" in comps
    assert comps["helper"].instrs[-1].opcode == "tanh"


def test_dryrun_artifacts_complete():
    """The committed sweep artifacts must cover all 80 cells, error-free."""
    import json
    import os
    rows = []
    for f in ("benchmarks/artifacts/dryrun_single.json",
              "benchmarks/artifacts/dryrun_multi.json"):
        if os.path.exists(f):
            rows += json.load(open(f))
    if not rows:
        pytest.skip("dry-run artifacts not generated yet")
    assert len(rows) == 80
    assert sum(r["status"] == "ok" for r in rows) == 66
    assert sum(r["status"] == "skip" for r in rows) == 14
    assert not any(r["status"] == "error" for r in rows)
    for r in rows:
        if r["status"] == "ok":
            assert r["hlo_flops"] > 0
            assert r["hlo_bytes"] > 0
            assert r["dominant"] in ("compute", "memory", "collective")
