"""Elastic fleet (distributed/fault_tolerance): the FleetController's
trigger/cool-down/guard machinery, the PreemptionCoordinator's memoized
survivor plans, rebalance_on_failure edge cases, plan_capacity_qps, and
the windowed run_elastic_fleet driver."""
import math

import numpy as np
import pytest

from repro.core.adaption import MonitorConfig, PlanMonitor, ReplanTrigger
from repro.core.admission import plan_capacity_qps
from repro.core.gears import PlanProvenance
from repro.core.scenarios import (DeviceRecover, Scenario, SpotPreemption,
                                  constant, ramp)
from repro.distributed.fault_tolerance import (FleetConfig, FleetController,
                                               PreemptionCoordinator,
                                               rebalance_on_failure,
                                               run_elastic_fleet)


def _trig(reason, t=0.0, qps=500.0):
    return ReplanTrigger(reason=reason, t=t, measured_qps=qps)


@pytest.fixture(scope="module")
def controller_parts(small_plan):
    report, hw = small_plan
    cfg = FleetConfig(min_devices=1, max_devices=6, cooldown=50.0,
                      shrink_guard=1.2, device_hour_price=2.0)
    return report, cfg


# ----------------------------------------------------------- FleetController

def test_scale_out_grows_and_cooldown_vetoes(controller_parts):
    report, cfg = controller_parts
    fc = FleetController(report.state, cfg, base_plan=report.plan)
    assert fc.n_devices == 4
    fc.request(_trig("scale-out"), 100.0)
    assert fc.act(100.0, recent_peak_qps=5000.0) is not None
    assert fc.n_devices == 5
    assert fc.plan.num_devices == 5
    # a second desire inside the cool-down window is vetoed
    fc.request(_trig("scale-out"), 120.0)
    assert fc.act(120.0, recent_peak_qps=5000.0) is None
    assert fc.n_devices == 5
    vetoed = fc.actions[-1]
    assert not vetoed.applied and vetoed.detail == "cooldown"
    # past the cool-down it applies, clamped at max_devices
    fc.request(_trig("scale-out"), 200.0)
    assert fc.act(200.0, recent_peak_qps=5000.0) is not None
    fc.request(_trig("scale-out"), 300.0)
    assert fc.act(300.0, recent_peak_qps=5000.0) is None   # at max 6
    assert fc.n_devices == 6


def test_shrink_guard_iso_slo(controller_parts):
    report, cfg = controller_parts
    fc = FleetController(report.state, cfg, base_plan=report.plan)
    # peak too high: 3 devices cannot hold guard x peak -> veto
    cap3 = plan_capacity_qps(fc.plan_for(3), report.state.profiles)
    fc.request(_trig("scale-in"), 100.0)
    assert fc.act(100.0, recent_peak_qps=cap3 / cfg.shrink_guard + 1.0) \
        is None
    assert fc.n_devices == 4
    assert "iso-SLO guard" in fc.actions[-1].detail
    # quiet peak: the shrink applies
    fc.request(_trig("scale-in"), 200.0)
    assert fc.act(200.0, recent_peak_qps=100.0) is not None
    assert fc.n_devices == 3


def test_plan_for_memoized_bit_identical(controller_parts):
    report, cfg = controller_parts
    fc = FleetController(report.state, cfg, base_plan=report.plan)
    p3a = fc.plan_for(3)
    p3b = fc.plan_for(3)
    assert p3a is p3b                       # memo, no second solve
    assert fc.plan_for(4) is report.plan    # base plan passed through
    assert p3a.num_devices == 3
    # the planned range scales with the fleet
    assert p3a.qps_max == pytest.approx(report.plan.qps_max * 3 / 4)


def test_capacity_monotone_in_fleet(controller_parts):
    report, cfg = controller_parts
    fc = FleetController(report.state, cfg, base_plan=report.plan)
    profiles = report.state.profiles
    caps = [plan_capacity_qps(fc.plan_for(n), profiles) for n in (2, 3, 4)]
    assert 0 < caps[0] < caps[1] < caps[2]


def test_grant_and_revoke_mandates(controller_parts):
    report, cfg = controller_parts
    fc = FleetController(report.state, cfg, base_plan=report.plan)
    fc.apply_fleet_event(0.0, "grant", 2)
    assert fc.max_devices == 8
    # revoke below the live fleet forces a shrink, ignoring cool-down
    fc.request(_trig("scale-out"), 10.0)
    fc.act(10.0, recent_peak_qps=1000.0)            # n = 5, cooldown armed
    forced = fc.apply_fleet_event(11.0, "revoke", 5)
    assert forced is not None
    assert fc.n_devices == fc.max_devices == 3
    with pytest.raises(ValueError):
        fc.apply_fleet_event(12.0, "lease", 1)


def test_cost_metering(controller_parts):
    report, cfg = controller_parts
    fc = FleetController(report.state, cfg, base_plan=report.plan)
    fc.meter(100.0)                                  # 100 s at 4 devices
    fc.request(_trig("scale-in"), 100.0)
    fc.act(100.0, recent_peak_qps=10.0)              # -> 3 devices
    fc.meter(200.0)                                  # 100 s at 3 devices
    assert fc.device_seconds == pytest.approx(100 * 4 + 100 * 3)
    assert fc.device_hours == pytest.approx(700 / 3600.0)
    assert fc.cost == pytest.approx(fc.device_hours * 2.0)


def test_start_devices_prewarms(controller_parts):
    report, cfg = controller_parts
    fc = FleetController(report.state, cfg, base_plan=report.plan,
                         start_devices=2)
    assert fc.n_devices == 2
    assert fc.plan.num_devices == 2
    with pytest.raises(ValueError):
        FleetController(report.state, cfg, base_plan=report.plan,
                        start_devices=99)


def test_monitor_emits_scale_triggers():
    prov = PlanProvenance(qps_max=400.0, n_ranges=4, qps_prior=(0.25,) * 4,
                          num_devices=2, mem_per_device=16e9)
    mon = PlanMonitor(prov, MonitorConfig(scale_out_frac=0.8,
                                          scale_out_ticks=2,
                                          scale_in_frac=0.25,
                                          scale_in_ticks=2, cooldown=0.0))
    assert mon.on_tick(1.0, 350.0) is None
    trig = mon.on_tick(2.0, 350.0)
    assert trig is not None and trig.reason == "scale-out"
    assert mon.on_tick(3.0, 50.0) is None
    trig = mon.on_tick(4.0, 50.0)
    assert trig is not None and trig.reason == "scale-in"


# ---------------------------------------------------- PreemptionCoordinator

def test_coordinator_memoizes_survivor_solve(bert_like_profiles,
                                             small_plan):
    report, _ = small_plan
    coord = PreemptionCoordinator(report.plan, bert_like_profiles)
    g1 = coord.on_failure(10.0, 3)          # drain notice: the one solve
    assert g1 is not None and coord.solves == 1
    g2 = coord.on_failure(18.0, 3)          # revoke: memo hit, O(1)
    assert g2 is g1
    assert coord.solves == 1 and coord.hits == 1


def test_coordinator_recovery_restores_original_bit_identically(
        bert_like_profiles, small_plan):
    report, _ = small_plan
    coord = PreemptionCoordinator(report.plan, bert_like_profiles)
    survivors = coord.on_failure(10.0, 3)
    restored = coord.on_recover(3)
    # empty down-set: the ORIGINAL gear list object, not a re-solve
    assert restored is report.plan.gears
    assert coord.down == set()
    # going down again reuses the memo for the same down-set
    again = coord.on_failure(20.0, 3)
    assert again is survivors and coord.solves == 1


def test_coordinator_none_when_no_gear_survives(bert_like_profiles,
                                                small_plan):
    report, hw = small_plan
    coord = PreemptionCoordinator(report.plan, bert_like_profiles)
    out = None
    for d in range(hw.num_devices):
        out = coord.on_failure(float(d), d)
    assert out is None and coord.infeasible >= 1


# ------------------------------------------------ rebalance_on_failure edges

def test_rebalance_last_replica_remaps_to_feasible_gear(
        bert_like_profiles, small_plan):
    """Kill every device hosting some model: gears whose cascade used it
    must be remapped to the nearest runnable gear, and every load
    fraction must point at a surviving replica."""
    report, _ = small_plan
    plan = report.plan
    by_model = {}
    for r in plan.replicas:
        by_model.setdefault(r.model, set()).add(r.device)
    # the model with the FEWEST hosting devices is the cheapest total loss
    victim, devs = min(by_model.items(), key=lambda kv: len(kv[1]))
    if len(devs) == len({r.device for r in plan.replicas}):
        pytest.skip("every model spans the whole fleet in this plan")
    fixed = rebalance_on_failure(plan, bert_like_profiles, set(devs))
    alive = {m for m, d in by_model.items() if d - devs}
    for g in fixed.gears:
        assert all(m in alive for m in g.cascade.models)
        for m, frac in g.load_fractions.items():
            for ridx, f in frac.items():
                if f > 0:
                    assert plan.replicas[ridx].device not in devs
    # replica indices are stable (queues are keyed by index)
    assert fixed.replicas == plan.replicas


def test_rebalance_total_loss_raises(bert_like_profiles, small_plan):
    report, hw = small_plan
    with pytest.raises(RuntimeError):
        rebalance_on_failure(report.plan, bert_like_profiles,
                             set(range(hw.num_devices)))


def test_rebalance_reentry_is_pure(bert_like_profiles, small_plan):
    """Same down-set twice: identical fractions (the LP resolve is
    deterministic), so recovery re-entry restores routing exactly."""
    report, _ = small_plan
    a = rebalance_on_failure(report.plan, bert_like_profiles, {3})
    b = rebalance_on_failure(report.plan, bert_like_profiles, {3})
    for ga, gb in zip(a.gears, b.gears):
        assert ga.load_fractions == gb.load_fractions


# ----------------------------------------------------------- windowed driver

def test_run_elastic_fleet_static_accounting(bert_like_profiles,
                                             small_plan):
    report, _ = small_plan
    sc = Scenario(traffic=constant(20, 1000.0), drain=2.0)
    r = run_elastic_fleet(bert_like_profiles, sc, plan=report.plan,
                          slo_latency=0.4, window=8.0)
    assert r.offered == 20 * 1000
    assert r.completed + r.shed == r.offered
    assert r.windows == 3                     # 8 + 8 + 4
    assert r.device_hours == pytest.approx(4 * 20 / 3600.0)
    assert 0.0 <= r.slo_attainment <= 1.0
    assert r.fleet_sizes == [(0.0, 4)]


def test_run_elastic_fleet_skips_out_of_range_events(bert_like_profiles,
                                                     small_plan):
    report, cfg = small_plan[0], FleetConfig(min_devices=2, max_devices=4,
                                             cooldown=0.0)
    fc = FleetController(report.state, cfg, base_plan=report.plan,
                         start_devices=2)
    sc = Scenario(traffic=constant(12, 200.0),
                  events=(SpotPreemption(t=4.0, device=3, lead=2.0),
                          DeviceRecover(t=9.0, device=3)),
                  drain=2.0)
    r = run_elastic_fleet(bert_like_profiles, sc, controller=fc,
                          slo_latency=0.4, window=6.0)
    # fleet stays at 2 (no triggers enabled): device-3 events are skipped
    assert r.skipped_events == 3              # drain + revoke + recover
    assert r.completed + r.shed == r.offered


def test_run_elastic_fleet_grows_under_ramp(bert_like_profiles,
                                            small_plan):
    report, _ = small_plan
    cfg = FleetConfig(min_devices=2, max_devices=4, cooldown=10.0)
    fc = FleetController(report.state, cfg, base_plan=report.plan,
                         start_devices=2)
    mon = MonitorConfig(scale_out_frac=0.5, scale_out_ticks=3,
                        cooldown=5.0)
    sc = Scenario(traffic=ramp(60, 500.0, 6000.0), drain=2.0)
    r = run_elastic_fleet(bert_like_profiles, sc, controller=fc,
                          monitor_cfg=mon, slo_latency=0.4, window=15.0)
    sizes = [n for _, n in r.fleet_sizes]
    assert sizes[0] == 2 and max(sizes) > 2   # the ramp grew the fleet
    assert all(b >= a for a, b in zip(sizes, sizes[1:]))
    assert r.completed + r.shed == r.offered


def test_run_elastic_fleet_arg_validation(bert_like_profiles, small_plan):
    report, _ = small_plan
    sc = Scenario(traffic=constant(5, 100.0))
    with pytest.raises(ValueError):
        run_elastic_fleet(bert_like_profiles, sc)       # neither arm
