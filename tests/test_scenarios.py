"""Scenario DSL (core/scenarios.py): builders, lowering, validation, and
the cross-driver determinism regression — one scenario with a flash crowd,
a spot preemption, and a recovery must produce bit-identical decision
traces from the scalar simulator and a single VecSim lane, and across two
runs of the same driver."""
import numpy as np
import pytest

from repro.core import DecisionTrace, ServingSimulator, SimConfig
from repro.core.scenarios import (CapacityGrant, CapacityRevoke, DeviceFail,
                                  DeviceRecover, DeviceSlowdown,
                                  NetworkDegradation, Scenario,
                                  SpotPreemption, constant, diurnal_noise,
                                  flash_crowd, ramp, spike)
from repro.core.vecsim import VecSim


# --------------------------------------------------------------- traffic DSL

def test_traffic_builders_render_shapes():
    assert len(constant(10, 100.0).render()) == 10
    assert len(ramp(20, 50.0, 500.0).render()) == 20
    assert len(diurnal_noise(days=2, day_seconds=30).render()) == 60
    s = spike(30, base_qps=100.0, spike_qps=900.0, at=10, length=5).render()
    assert s.max() > 100.0 and s[0] == pytest.approx(100.0)
    f = flash_crowd(40, base_qps=100.0, peak_qps=800.0, at=10).render()
    assert f.max() <= 800.0 + 1e-9 and f[:10].max() == pytest.approx(100.0)


def test_traffic_compose_and_scale():
    a, b = constant(10, 100.0), constant(10, 50.0)
    assert np.allclose((a + b).render(), 150.0)
    assert np.allclose(a.scaled(2.0).render(), 200.0)


def test_traffic_render_deterministic():
    t1 = diurnal_noise(days=1, day_seconds=50, noise=0.2, seed=9).render()
    t2 = diurnal_noise(days=1, day_seconds=50, noise=0.2, seed=9).render()
    assert np.array_equal(t1, t2)


# ----------------------------------------------------------------- lowering

def test_spot_preemption_lowers_to_drain_plus_revoke():
    sc = Scenario(traffic=constant(60, 100.0),
                  events=(SpotPreemption(t=10.0, device=2, lead=5.0),))
    evs = sc.device_events()
    assert (10.0, 2, "drain", 5.0) in evs
    assert (15.0, 2, "revoke", 0.0) in evs


def test_zero_lead_preemption_is_hard_revoke():
    sc = Scenario(traffic=constant(30, 100.0),
                  events=(SpotPreemption(t=10.0, device=1, lead=0.0),))
    evs = sc.device_events()
    assert evs == [(10.0, 1, "revoke", 0.0)]


def test_hard_fail_variant_strips_leads():
    sc = Scenario(traffic=constant(60, 100.0),
                  events=(SpotPreemption(t=10.0, device=2, lead=5.0),
                          DeviceRecover(t=40.0, device=2)))
    hard = sc.hard_fail_variant()
    evs = hard.device_events()
    # the revoke lands at the SAME wall-clock instant, without the notice
    assert (15.0, 2, "revoke", 0.0) in evs
    assert not any(k == "drain" for _, _, k, _ in evs)
    # non-preemption events pass through untouched
    assert (40.0, 2, "recover", 1.0) in evs


def test_event_lowering_sorted_and_mixed():
    sc = Scenario(traffic=constant(120, 100.0), events=(
        NetworkDegradation(t=50.0, until=60.0, factor=2.0),
        DeviceSlowdown(t=5.0, device=0, factor=3.0),
        DeviceFail(t=20.0, device=1),
        SpotPreemption(t=30.0, device=2, lead=10.0)))
    evs = sc.device_events()
    assert evs == sorted(evs, key=lambda e: e[0])
    kinds = {k for _, _, k, _ in evs}
    assert kinds == {"netdeg", "slow", "fail", "drain", "revoke"}


def test_fleet_events_lowering():
    sc = Scenario(traffic=constant(60, 100.0),
                  events=(CapacityGrant(t=10.0, devices=2),
                          CapacityRevoke(t=30.0, devices=1)))
    assert sc.fleet_events() == [(10.0, "grant", 2), (30.0, "revoke", 1)]
    assert sc.device_events() == []


def test_scenario_validation():
    with pytest.raises(ValueError):
        Scenario(traffic=constant(10, 100.0), drain=-1.0)


# ----------------------------------------------- cross-driver determinism

@pytest.fixture(scope="module")
def chaos_scenario():
    return Scenario(
        traffic=flash_crowd(40, base_qps=300.0, peak_qps=1200.0, at=10),
        events=(SpotPreemption(t=12.0, device=3, lead=6.0),
                DeviceRecover(t=30.0, device=3)),
        drain=2.0, name="determinism-regression")


def test_scenario_determinism_across_drivers(bert_like_profiles, small_plan,
                                             chaos_scenario):
    report, hw = small_plan
    plan = report.plan
    cfg = SimConfig()
    sim = ServingSimulator(bert_like_profiles, plan.replicas,
                           hw.num_devices, cfg)
    vec = VecSim(bert_like_profiles, plan.replicas, hw.num_devices, cfg)

    tr_sim, tr_sim2, tr_vec = (DecisionTrace() for _ in range(3))
    r_sim = sim.run_trace(plan, scenario=chaos_scenario,
                          decision_trace=tr_sim)
    r_sim2 = sim.run_trace(plan, scenario=chaos_scenario,
                           decision_trace=tr_sim2)
    r_vec = vec.run_trace(plan, scenario=chaos_scenario,
                          decision_trace=tr_vec)

    # same driver, two runs: bit-identical
    assert tr_sim.routes == tr_sim2.routes
    assert tr_sim.fires == tr_sim2.fires
    assert tr_sim.hops == tr_sim2.hops
    assert r_sim.completed == r_sim2.completed
    # scalar vs lane-batched: bit-identical decision streams
    assert tr_sim.routes == tr_vec.routes
    assert tr_sim.gear_switches == tr_vec.gear_switches
    assert tr_sim.fires == tr_vec.fires
    assert tr_sim.hops == tr_vec.hops
    assert r_sim.completed == r_vec.completed
    assert r_sim.shed == r_vec.shed
    # the preemption actually bit: some decisions happened post-notice
    assert any(f[0] >= 0 for f in tr_sim.fires)


def test_scenario_exclusive_with_explicit_args(bert_like_profiles,
                                               small_plan, chaos_scenario):
    report, hw = small_plan
    sim = ServingSimulator(bert_like_profiles, report.plan.replicas,
                           hw.num_devices)
    with pytest.raises(ValueError):
        sim.run_trace(report.plan, np.full(5, 100.0),
                      scenario=chaos_scenario)


# ------------------------------------------------------- revoke semantics

def test_revoke_sheds_drain_saves(bert_like_profiles, small_plan):
    """The drain window's entire value: a warned preemption sheds strictly
    fewer requests than the same machine vanishing unannounced."""
    from repro.distributed.fault_tolerance import PreemptionCoordinator
    report, hw = small_plan
    plan = report.plan
    sim = ServingSimulator(bert_like_profiles, plan.replicas,
                           hw.num_devices)
    base = dict(traffic=constant(30, 6000.0), drain=2.0)
    warned = Scenario(events=(SpotPreemption(t=15.0, device=3, lead=8.0),),
                      **base)
    coord = PreemptionCoordinator(plan, bert_like_profiles)
    r_warn = sim.run_trace(plan, scenario=warned,
                           on_failure=coord.on_failure)
    coord.reset(plan)
    r_hard = sim.run_trace(plan, scenario=warned.hard_fail_variant(),
                           on_failure=coord.on_failure)
    # hard revoke loses the resident queue + in-flight batch
    assert r_hard.shed > 0
    assert r_warn.shed < r_hard.shed
    # conservation: every offered sample is completed, still in flight,
    # or accounted as shed — nothing vanishes silently
    for r in (r_warn, r_hard):
        assert r.completed + r.backlog_end + r.shed == r.offered


def test_fail_still_replays_everything(bert_like_profiles, small_plan):
    """`fail` keeps replay semantics (crash, not revoke): nothing is shed
    and the re-issued work completes on the survivors."""
    report, hw = small_plan
    plan = report.plan
    sim = ServingSimulator(bert_like_profiles, plan.replicas,
                           hw.num_devices)
    r = sim.run_trace(plan, np.full(20, 2000.0), drain=5.0,
                      device_events=[(10.0, 3, "fail", 0.0)])
    assert r.shed == 0
    assert r.completed + r.backlog_end == r.offered


def test_hedge_budget_refund_on_preemption(bert_like_profiles, small_plan):
    """Hedge/preemption interaction: a hedged duplicate parked on the
    preempted device is refunded (the fleet, not the straggler history,
    killed it), so hedging composes with drain windows without stranding
    samples or double-charging the per-batch budget."""
    from repro.distributed.fault_tolerance import HedgePolicy
    report, hw = small_plan
    plan = report.plan
    sim = ServingSimulator(bert_like_profiles, plan.replicas,
                           hw.num_devices)
    sc = Scenario(traffic=constant(30, 3000.0),
                  events=(DeviceSlowdown(t=5.0, device=1, factor=12.0),
                          SpotPreemption(t=12.0, device=2, lead=4.0),
                          DeviceRecover(t=22.0, device=2)),
                  drain=5.0)
    hedge = HedgePolicy(hedge_multiplier=2.0, max_hedges_per_batch=1)
    r = sim.run_trace(plan, scenario=sc, hedge=hedge)
    r_plain = sim.run_trace(plan, scenario=sc)
    assert r.completed + r.backlog_end + r.shed == r.offered
    # hedging must not LOSE completions relative to the unhedged run
    assert r.completed >= r_plain.completed


# --------------------------------------------------- entry validation (all
# three drivers run validate_device_events before simulating)

@pytest.mark.parametrize("events,match", [
    ([(5.0, 0, "explode", 0.0)], "unknown kind"),
    ([(5.0, 99, "fail", 0.0)], "out of range"),
    ([(10.0, 0, "fail", 0.0), (5.0, 1, "fail", 0.0)], "sorted"),
    ([(5.0, 0, "slow", -1.0)], "slow-down factor"),
    ([(5.0, 0, "netdeg", 2.0)], "fleet-wide"),
    ([(-1.0, 0, "fail", 0.0)], "time must be"),
    ([("bad",)], "tuple"),
])
def test_validate_device_events_rejects(bert_like_profiles, small_plan,
                                        events, match):
    report, hw = small_plan
    trace = np.full(5, 50.0)
    sim = ServingSimulator(bert_like_profiles, report.plan.replicas,
                           hw.num_devices)
    vec = VecSim(bert_like_profiles, report.plan.replicas, hw.num_devices)
    with pytest.raises(ValueError, match=match):
        sim.run_trace(report.plan, trace, device_events=events)
    with pytest.raises(ValueError, match=match):
        vec.run_trace(report.plan, trace, device_events=events)
