"""Fusion-aware byte accounting + top_contributors diagnostics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.profiling.hlo_cost import (analyze_hlo_text, parse_hlo,
                                      top_contributors)


def test_inplace_dus_counts_update_only():
    """A scan that writes one row per step must cost O(rows), not
    O(rows x buffer) — in-place dynamic-update-slice accounting."""
    n, d = 64, 256

    def write_rows(buf, xs):
        def body(b, x):
            i = x[0].astype(jnp.int32)
            return jax.lax.dynamic_update_slice(b, x[1][None], (i, 0)), None
        out, _ = jax.lax.scan(body, buf, xs)
        return out

    buf = jax.ShapeDtypeStruct((n, d), jnp.float32)
    xs = (jax.ShapeDtypeStruct((n,), jnp.float32),
          jax.ShapeDtypeStruct((n, d), jnp.float32))
    c = jax.jit(write_rows).lower(buf, xs).compile()
    s = analyze_hlo_text(c.as_text())
    # full-buffer-per-step accounting would be n * n * d * 4 = 16.7 MB;
    # the real traffic is O(n * d): row read+write per step + xs streams
    assert s.bytes_accessed < n * d * 4 * 12, s.bytes_accessed


def test_sliced_weight_stack_counts_slices():
    """Scan over stacked weights reads one layer per trip, not the stack."""
    reps, d = 16, 128

    def run(x, stack):
        def body(h, w):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, stack)
        return h

    x = jax.ShapeDtypeStruct((d, d), jnp.float32)
    stack = jax.ShapeDtypeStruct((reps, d, d), jnp.float32)
    c = jax.jit(run).lower(x, stack).compile()
    s = analyze_hlo_text(c.as_text())
    # flops exact: reps matmuls
    assert s.flops == pytest.approx(reps * 2 * d ** 3, rel=0.01)
    # bytes: the weight-slice fusion must charge O(slice) per trip —
    # naive accounting charges the whole (reps, d, d) stack each trip
    slice_bytes = d * d * 4
    top = top_contributors(c.as_text(), k=4, metric="bytes")
    slice_rows = [v for v, desc in top if "dynamic-slice" in desc]
    assert slice_rows, "expected a dynamic-slice fusion among top ops"
    per_trip = slice_rows[0] / reps
    assert per_trip <= 4 * slice_bytes, per_trip
    # and the total is far from the naive O(reps x stack) blow-up
    assert s.bytes_accessed < 0.7 * reps * reps * d * d * 4


def test_top_contributors_finds_the_dominant_op():
    def f(a, b, c):
        return (a @ b) @ c
    a = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    cc = jax.ShapeDtypeStruct((512, 64), jnp.float32)
    comp = jax.jit(f).lower(a, b, cc).compile()
    top = top_contributors(comp.as_text(), k=3, metric="flops")
    assert top
    # the 512x512x512 dot dominates the 512x512x64 one
    assert top[0][0] == pytest.approx(2 * 512 ** 3, rel=0.01)


def test_conditional_takes_max_branch():
    def f(pred, x):
        return jax.lax.cond(pred, lambda v: v @ v, lambda v: v + 1.0, x)
    p = jax.ShapeDtypeStruct((), jnp.bool_)
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = jax.jit(f).lower(p, x).compile()
    s = analyze_hlo_text(c.as_text())
    assert s.flops >= 2 * 128 ** 3 * 0.99  # upper-bound branch counted
