"""Plan lifecycle (core/adaption.py): drift detection, background
re-planning, atomic hot-swap — unit coverage plus the end-to-end drift
scenario (offered QPS ramping past the planned range triggers a background
re-plan and a hot-swap that restores stability)."""
import time

import numpy as np
import pytest

from repro.core import (BackgroundReplanner, HardwareSpec, MonitorConfig,
                        PlanLifecycle, PlanMonitor, SLO, ServingSimulator,
                        SimConfig, optimize_gear_plan, planner_replan_fn,
                        provenance_for_plan)
from repro.core.adaption import PlanVersion, ReplanTrigger
from repro.core.cascade import Cascade
from repro.core.gears import GearPlan, PlanProvenance
from repro.core.lp import Replica
from repro.core.plan_state import InfeasiblePlanError
from repro.core.profiles import synthetic_family
from repro.core.simulator import make_gear


def _prov(qps_max=400.0, n_devices=2, **kw):
    return PlanProvenance(qps_max=qps_max, n_ranges=4,
                          qps_prior=(0.25,) * 4, num_devices=n_devices,
                          mem_per_device=16e9, **kw)


def _tiny_plan(profiles, reps, qps_max=400.0):
    g = make_gear(Cascade(("a", "b"), (0.3,)), reps)
    return GearPlan(qps_max=qps_max, gears=[g], replicas=reps,
                    num_devices=2, slo=SLO(kind="latency", latency_p95=1.0))


@pytest.fixture(scope="module")
def slow_family():
    # ratio 6: the big model sustains ~500 qps at full batching — above
    # what the accurate cascade forwards at qps_max=400, far below it at
    # 2x, so "load beyond the planned range" genuinely breaks the top gear
    return synthetic_family(["a", "b"], base_runtime=2e-3,
                            runtime_ratio=6.0, base_acc=0.7, acc_gain=0.08,
                            mem_base=0.4e9, seed=5)


# ---------------------------------------------------------------------------
# PlanMonitor
# ---------------------------------------------------------------------------

def test_monitor_qps_exceeds_range_needs_sustain():
    mon = PlanMonitor(_prov(400.0),
                      MonitorConfig(qps_sustain_ticks=3, cooldown=100.0))
    assert mon.on_tick(0.1, 500.0) is None
    assert mon.on_tick(0.2, 500.0) is None
    trig = mon.on_tick(0.3, 500.0)
    assert trig is not None and trig.reason == "qps-exceeds-range"
    assert trig.qps_window[-1] == 500.0
    # cooldown: no re-trigger storm
    assert mon.on_tick(0.4, 500.0) is None


def test_monitor_sustain_resets_below_range():
    mon = PlanMonitor(_prov(400.0),
                      MonitorConfig(qps_sustain_ticks=3, cooldown=0.0))
    mon.on_tick(0.1, 500.0)
    mon.on_tick(0.2, 500.0)
    mon.on_tick(0.3, 100.0)      # dips back into range -> counter resets
    assert mon.on_tick(0.4, 500.0) is None
    assert mon.on_tick(0.5, 500.0) is None
    assert mon.on_tick(0.6, 500.0) is not None


def test_monitor_device_loss():
    mon = PlanMonitor(_prov(400.0, n_devices=4),
                      MonitorConfig(device_loss_ticks=2, cooldown=100.0))
    mon.observe_devices(3)
    assert mon.on_tick(0.1, 10.0) is None
    trig = mon.on_tick(0.2, 10.0)
    assert trig is not None and trig.reason == "device-loss"
    assert "3/4" in trig.detail


def test_monitor_device_loss_reports_each_level_once():
    """A pinned-placement re-plan cannot revive devices, so the same loss
    level must not re-trigger forever (planner-cycle storm); a DEEPER loss
    or a full recovery re-arms the trigger."""
    mon = PlanMonitor(_prov(400.0, n_devices=4),
                      MonitorConfig(device_loss_ticks=2, cooldown=0.0))
    mon.observe_devices(3)
    mon.on_tick(0.1, 10.0)
    assert mon.on_tick(0.2, 10.0).reason == "device-loss"
    for i in range(6):                       # same level: reported once
        assert mon.on_tick(0.3 + 0.1 * i, 10.0) is None
    mon.observe_devices(2)                   # deeper loss re-arms at once
    trig = mon.on_tick(1.0, 10.0)            # (sustain already satisfied)
    assert trig is not None and trig.reason == "device-loss"
    mon.observe_devices(4)                   # full recovery re-arms
    mon.on_tick(1.2, 10.0)
    mon.observe_devices(3)
    mon.on_tick(1.3, 10.0)
    assert mon.on_tick(1.4, 10.0).reason == "device-loss"


def test_monitor_device_count_survives_rebase():
    """Device aliveness is world state, not per-plan drift state: a device
    still dead across a hot-swap must stay visible to loss detection."""
    mon = PlanMonitor(_prov(400.0, n_devices=2),
                      MonitorConfig(device_loss_ticks=2, cooldown=0.0))
    mon.observe_devices(1)
    mon.rebase(_prov(800.0, n_devices=2), t=5.0)   # swap happened
    mon.on_tick(5.1, 10.0)
    trig = mon.on_tick(5.2, 10.0)
    assert trig is not None and trig.reason == "device-loss"


def test_monitor_certainty_drift():
    mon = PlanMonitor(_prov(400.0, cert_means=(("a", 0.8),)),
                      MonitorConfig(cert_drift_threshold=0.1,
                                    cert_min_samples=10, cooldown=100.0))
    for _ in range(9):
        mon.observe_cert("a", 0.4)
    assert mon.on_tick(0.1, 10.0) is None      # below min sample count
    mon.observe_cert("a", 0.4)
    trig = mon.on_tick(0.2, 10.0)
    assert trig is not None and trig.reason == "certainty-drift"
    # rebase clears the drift state (tick past the post-rebase cooldown,
    # so the None verdict comes from the drift check, not the quiet period)
    mon.rebase(_prov(400.0, cert_means=(("a", 0.4),)), t=0.2)
    for _ in range(20):
        mon.observe_cert("a", 0.4)
    assert mon.on_tick(150.0, 10.0) is None


def test_monitor_certainty_drift_reports_once_per_drift():
    """Pinned re-plans keep the same profiles, so an unresolved drift must
    not re-trigger a futile optimizer run every cooldown; recovery (e.g. a
    re-profiled reference) re-arms the trigger."""
    mon = PlanMonitor(_prov(400.0, cert_means=(("a", 0.8),)),
                      MonitorConfig(cert_drift_threshold=0.1,
                                    cert_min_samples=5, cooldown=0.0))
    for _ in range(5):
        mon.observe_cert("a", 0.4)
    assert mon.on_tick(0.1, 10.0).reason == "certainty-drift"
    for i in range(5):                       # same drift: reported once
        assert mon.on_tick(0.2 + 0.1 * i, 10.0) is None
    for _ in range(200):                     # mean recovers -> re-armed
        mon.observe_cert("a", 0.8)
    assert mon.on_tick(1.0, 10.0) is None
    for _ in range(400):                     # fresh drift fires again
        mon.observe_cert("a", 0.1)
    assert mon.on_tick(1.1, 10.0).reason == "certainty-drift"


def test_threaded_server_flips_replanner_to_background(slow_family):
    """start() must move the optimiser off the producer tick: the
    wall-clock server flips its replanner to daemon-thread mode (the
    deterministic run_virtual path never starts threads, so it keeps the
    synchronous publish-at-latency semantics)."""
    from repro.serving.runtime import CascadeServer
    reps = [Replica(m, d, slow_family[m].runtime_per_sample(1.0))
            for d in range(2) for m in slow_family]
    plan = _tiny_plan(slow_family, reps)
    rp = BackgroundReplanner(lambda trig, active: plan, plan_latency=0.0)
    lc = PlanLifecycle(plan, monitor=PlanMonitor(provenance_for_plan(plan)),
                       replanner=rp)
    server = CascadeServer(plan, engines={}, lifecycle=lc)
    assert not rp.threaded
    server.start()
    try:
        assert rp.threaded
    finally:
        server.stop()


# ---------------------------------------------------------------------------
# BackgroundReplanner
# ---------------------------------------------------------------------------

def _version(plan):
    return PlanVersion(epoch=0, plan=plan,
                       provenance=plan.provenance or
                       provenance_for_plan(plan))


def test_replanner_publishes_after_latency(slow_family):
    reps = [Replica(m, d, slow_family[m].runtime_per_sample(1.0))
            for d in range(2) for m in slow_family]
    plan = _tiny_plan(slow_family, reps)
    new_plan = _tiny_plan(slow_family, reps, qps_max=900.0)
    rp = BackgroundReplanner(lambda trig, active: new_plan,
                             plan_latency=0.5)
    trig = ReplanTrigger("qps-exceeds-range", 1.0, 800.0)
    assert rp.submit(trig, _version(plan), t=1.0)
    assert not rp.submit(trig, _version(plan), t=1.1)   # one at a time
    assert rp.poll(1.2) is None                          # not due yet
    out = rp.poll(1.6)
    assert out is not None and out.epoch == 1
    assert out.plan.qps_max == 900.0
    assert rp.poll(1.7) is None                          # published once


def test_replanner_infeasible_records_failure(slow_family):
    reps = [Replica(m, d, slow_family[m].runtime_per_sample(1.0))
            for d in range(2) for m in slow_family]
    plan = _tiny_plan(slow_family, reps)

    def boom(trig, active):
        raise InfeasiblePlanError("drifted workload unservable")

    rp = BackgroundReplanner(boom, plan_latency=0.0)
    rp.submit(ReplanTrigger("qps-exceeds-range", 0.0, 800.0),
              _version(plan), t=0.0)
    assert rp.poll(0.1) is None
    assert len(rp.failures) == 1 and "unservable" in rp.failures[0][1]
    assert not rp.busy                                  # slot freed

    # ANY plan_fn exception degrades to keep-serving, never a crash
    def bug(trig, active):
        raise ValueError("numerics blew up")

    rp2 = BackgroundReplanner(bug, plan_latency=0.0)
    rp2.submit(ReplanTrigger("qps-exceeds-range", 0.0, 800.0),
               _version(plan), t=0.0)
    assert rp2.poll(0.1) is None
    assert "ValueError" in rp2.failures[0][1]


def test_replanner_threaded_mode(slow_family):
    reps = [Replica(m, d, slow_family[m].runtime_per_sample(1.0))
            for d in range(2) for m in slow_family]
    plan = _tiny_plan(slow_family, reps)
    new_plan = _tiny_plan(slow_family, reps, qps_max=900.0)
    rp = BackgroundReplanner(lambda trig, active: new_plan,
                             plan_latency=0.0, threaded=True)
    t0 = time.monotonic()
    rp.submit(ReplanTrigger("qps-exceeds-range", t0, 800.0),
              _version(plan), t=t0)
    out = None
    for _ in range(200):                 # thread hand-off, bounded wait
        out = rp.poll(time.monotonic())
        if out is not None:
            break
        time.sleep(0.01)
    assert out is not None and out.plan.qps_max == 900.0


# ---------------------------------------------------------------------------
# PlanLifecycle
# ---------------------------------------------------------------------------

def test_frozen_lifecycle_never_swaps(slow_family):
    """Baseline plans are swap-frozen: triggers are observed but no re-plan
    is ever submitted (the ablation must stay honest)."""
    from repro.serving.baselines import MSPlusPolicy
    hw = HardwareSpec(num_devices=2, mem_per_device=16e9)
    plan, _ = MSPlusPolicy(n_ranges=4).build_plan(
        slow_family, hw, SLO(kind="latency", latency_p95=1.0), 400.0)
    assert plan.provenance is not None and plan.provenance.frozen
    calls = []
    rp = BackgroundReplanner(lambda trig, active: calls.append(1) or plan,
                             plan_latency=0.0)
    lc = PlanLifecycle(plan, monitor=PlanMonitor(
        plan.provenance, MonitorConfig(qps_sustain_ticks=2, cooldown=0.0)),
        replanner=rp)
    for i in range(10):
        assert lc.step(0.1 * (i + 1), 900.0, 0) is None
    assert lc.triggers                      # drift WAS detected...
    assert not calls and not lc.swaps       # ...but never acted upon


def test_swap_selector_adopts_driver_alpha(slow_family):
    """Post-swap selectors must keep the driver's tuned hysteresis alpha,
    not silently reset to the default."""
    from repro.core import SchedulerConfig, SchedulerCore
    reps = [Replica(m, d, slow_family[m].runtime_per_sample(1.0))
            for d in range(2) for m in slow_family]
    g0 = make_gear(Cascade(("a", "b"), (0.3,)), reps)
    g1 = make_gear(Cascade(("a",), ()), reps)
    plan = GearPlan(qps_max=400.0, gears=[g0, g1], replicas=reps,
                    num_devices=2, slo=SLO(kind="latency", latency_p95=1.0))
    core = SchedulerCore(reps, SchedulerConfig(alpha=2.0))
    lc = PlanLifecycle(plan)
    lc.attach(core)
    sel = lc.selector_factory(plan)
    # downgrade 1->0 at measured=100 with q0=20: alpha=2 allows it
    # (100 >= 2*20); the default alpha=8 would hold the current gear
    assert sel(0.0, 100.0, 1, 20) == 0


def test_placement_incompatible_plan_rejected(slow_family):
    reps = [Replica(m, d, slow_family[m].runtime_per_sample(1.0))
            for d in range(2) for m in slow_family]
    plan = _tiny_plan(slow_family, reps)
    moved = [Replica(r.model, (r.device + 1) % 2, r.runtime_per_sample)
             for r in reps]
    bad = GearPlan(qps_max=900.0,
                   gears=[make_gear(Cascade(("a",), ()), moved)],
                   replicas=moved, num_devices=2, slo=plan.slo)
    lc = PlanLifecycle(plan, monitor=PlanMonitor(
        provenance_for_plan(plan), MonitorConfig(qps_sustain_ticks=1,
                                                 cooldown=100.0)),
        replanner=BackgroundReplanner(lambda t_, a_: bad, plan_latency=0.0))
    lc.step(0.1, 900.0, 0)                  # trigger + submit
    assert lc.step(0.2, 900.0, 0) is None   # publish refused
    assert lc.active.plan is plan           # still serving the old plan
    assert any("placement-incompatible" in msg
               for _, msg in lc.replanner.failures)


# ---------------------------------------------------------------------------
# End-to-end drift scenario (the acceptance scenario, simulator side)
# ---------------------------------------------------------------------------

def test_drift_scenario_replans_and_recovers(slow_family):
    """Offered QPS ramps to 2x qps_max: the monitor fires
    ``qps-exceeds-range``, the background planner (warm-started, placement
    pinned) publishes an extended plan, the swap is applied atomically,
    and the simulator finishes the trace stably — while the identical run
    WITHOUT a lifecycle is left clamped to the top gear with a growing
    backlog."""
    profiles = slow_family
    hw = HardwareSpec(num_devices=2, mem_per_device=16e9)
    slo = SLO(kind="latency", latency_p95=1.0)
    report = optimize_gear_plan(profiles, hw, slo, qps_max=400.0,
                                n_ranges=4)
    plan = report.plan
    assert plan.provenance is not None          # planner records provenance
    assert plan.provenance.qps_max == 400.0
    assert plan.provenance.profile_digest

    # 4s in range, then 16s at 2x qps_max (long enough that the clamped
    # control cannot hide the deficit in the drain)
    trace = np.concatenate([np.full(4, 300.0), np.full(16, 800.0)])
    sim = ServingSimulator(profiles, plan.replicas, 2, SimConfig())

    def run(lifecycle):
        return sim.run_trace(plan, trace, drain=2.0, lifecycle=lifecycle)

    lc = PlanLifecycle(
        plan,
        monitor=PlanMonitor(plan.provenance,
                            MonitorConfig(qps_sustain_ticks=5,
                                          cooldown=30.0)),
        replanner=BackgroundReplanner(
            planner_replan_fn(profiles, hw, slo, n_ranges=4,
                              warm_state=report.state),
            plan_latency=1.0))
    res = run(lc)
    control = run(None)

    assert len(res.plan_swaps) >= 1
    t_swap, epoch, reason = res.plan_swaps[0]
    assert reason == "qps-exceeds-range" and epoch == 1
    assert lc.active.plan.qps_max >= 800.0      # range actually extended
    # placement was pinned: the swapped plan is index-compatible
    assert [(r.model, r.device) for r in lc.active.plan.replicas] == \
        [(r.model, r.device) for r in plan.replicas]
    # the re-planned run absorbs the drift; the clamped control does not
    assert res.stable
    assert not control.stable
    assert res.completed > control.completed