"""Decision parity between the two executors (the planner's fidelity
contract, paper §5 / App. C / Fig. 13).

The same gear plan, profiles and arrival schedule are fed through the
discrete-event ``ServingSimulator`` and the real ``CascadeServer`` (driven
in virtual time so its threads' wall clock is out of the picture), both
delegating every decision to the shared ``SchedulerCore``. The recorded
decision traces — replica routing, gear switches (α-hysteresis), batch
firings (min-queue trigger + head-of-line timeout), and cascade
continuations — must be *identical*, element for element.

Plus unit coverage of the core's four decision functions.
"""
import numpy as np
import pytest

from repro.core import (DecisionTrace, RoutePool, SchedulerConfig,
                        SchedulerCore, ServingSimulator, SimConfig,
                        plan_target, with_hysteresis)
from repro.core.cascade import Cascade
from repro.core.gears import GearPlan, SLO
from repro.core.lp import Replica
from repro.core.scheduling import CascadeHop, Resolved, head_of_line_wait
from repro.core.simulator import make_gear, trace_to_arrivals
from repro.serving.runtime import CascadeServer, Request


class _ReplayEngine:
    """Fake engine: emits each request's profile-recorded certainty in
    scores[:, 0] (tokens[0] carries the rid), so the runtime replays the
    exact validation behaviour the simulator replays."""

    def __init__(self, certs):
        self.certs = np.asarray(certs, np.float64)

    def infer(self, tokens):
        vi = np.asarray(tokens)[:, 0] % len(self.certs)
        out = np.zeros((len(vi), 2))
        out[:, 0] = self.certs[vi]
        return out


def _cert_estimator(scores):
    return scores[:, 0]


def _setup(profiles):
    models = ("tiny", "base")
    reps = [Replica(m, d, profiles[m].runtime_per_sample(1.0))
            for d in range(2) for m in models]
    # gear 0: cascade with a real threshold + batch trigger > 1 (so the
    # head-of-line timeout path fires); gear 1: cheap single model
    g0 = make_gear(Cascade(models, (0.35,)), reps, {"tiny": 2})
    g1 = make_gear(Cascade(("tiny",), ()), reps, {"tiny": 4})
    plan = GearPlan(qps_max=400.0, gears=[g0, g1], replicas=reps,
                    num_devices=2, slo=SLO(kind="latency", latency_p95=1.0))
    # load step up (forces an upshift) then back down (hysteresis +
    # downshift), long enough to drain
    trace = np.concatenate([np.full(3, 40.0), np.full(3, 350.0),
                            np.full(4, 40.0)])
    return reps, plan, trace


def test_executors_make_identical_decisions(bert_like_profiles):
    profiles = bert_like_profiles
    reps, plan, trace = _setup(profiles)
    n_arr = len(trace_to_arrivals(trace))

    tr_sim = DecisionTrace()
    sim = ServingSimulator(profiles, plan.replicas, 2,
                           SimConfig(max_batch=128))
    res = sim.run_trace(plan, trace, decision_trace=tr_sim)

    tr_srv = DecisionTrace()
    engines = {m: _ReplayEngine(profiles[m].validation.certs)
               for m in ("tiny", "base")}
    server = CascadeServer(
        plan, engines, estimator=_cert_estimator, max_batch=128,
        route_pool=RoutePool.for_arrivals(0, n_arr),
        decision_trace=tr_srv)
    reqs = [Request(rid=i, tokens=np.array([i], np.int64))
            for i in range(n_arr)]
    done = server.run_virtual(
        reqs, trace, batch_runtime=lambda m, b: profiles[m].runtime(b))

    # the scenario must actually exercise every decision type
    assert len(tr_sim.gear_switches) >= 2     # up AND back down
    assert len(tr_sim.fires) > 10
    assert any(h[2] != "resolve" for h in tr_sim.hops)   # cascaded work
    assert any(h[2] == "resolve" for h in tr_sim.hops)

    # decision-trace equality, element for element
    assert tr_sim.routes == tr_srv.routes
    assert tr_sim.gear_switches == tr_srv.gear_switches
    assert tr_sim.fires == tr_srv.fires
    assert tr_sim.hops == tr_srv.hops

    # and the executors agree end-to-end
    assert res.completed == len(done)
    srv_by_rid = {r.rid: r for r in done}
    assert res.completed == res.offered == len(srv_by_rid)


def test_hot_swap_decision_parity(bert_like_profiles):
    """Mid-run plan hot-swap (core/adaption.py) through BOTH executors:
    offered QPS ramps to 2x the plan's qps_max, the monitor triggers, the
    background re-planner publishes an epoch-1 plan, and the swap-aware
    decision traces — including the swap epochs and the QPS-range gear
    remap — must stay element-wise identical."""
    from repro.core.adaption import (BackgroundReplanner, MonitorConfig,
                                     PlanLifecycle, PlanMonitor,
                                     provenance_for_plan)

    profiles = bert_like_profiles
    reps, plan, _ = _setup(profiles)
    # ramp to 2x qps_max (400): sustained over-range ticks trigger a swap
    trace = np.concatenate([np.full(3, 40.0), np.full(4, 800.0),
                            np.full(4, 40.0)])
    n_arr = len(trace_to_arrivals(trace))

    # deterministic "re-planned" plan over the SAME replicas: wider range,
    # different gear table (a swap must remap the gear index by QPS range)
    g0 = make_gear(Cascade(("tiny", "base"), (0.2,)), reps, {"tiny": 4})
    g1 = make_gear(Cascade(("tiny",), ()), reps, {"tiny": 8})
    new_plan = GearPlan(qps_max=1000.0, gears=[g0, g1], replicas=reps,
                        num_devices=2, slo=plan.slo)

    def lifecycle():
        return PlanLifecycle(
            plan,
            monitor=PlanMonitor(provenance_for_plan(plan),
                                MonitorConfig(qps_sustain_ticks=3,
                                              cooldown=100.0)),
            replanner=BackgroundReplanner(lambda trig, active: new_plan,
                                          plan_latency=0.5))

    tr_sim = DecisionTrace()
    sim = ServingSimulator(profiles, plan.replicas, 2,
                           SimConfig(max_batch=128))
    lc_sim = lifecycle()
    res = sim.run_trace(plan, trace, decision_trace=tr_sim,
                        lifecycle=lc_sim)

    tr_srv = DecisionTrace()
    engines = {m: _ReplayEngine(profiles[m].validation.certs)
               for m in ("tiny", "base")}
    lc_srv = lifecycle()
    server = CascadeServer(
        plan, engines, estimator=_cert_estimator, max_batch=128,
        route_pool=RoutePool.for_arrivals(0, n_arr),
        decision_trace=tr_srv, lifecycle=lc_srv)
    reqs = [Request(rid=i, tokens=np.array([i], np.int64))
            for i in range(n_arr)]
    done = server.run_virtual(
        reqs, trace, batch_runtime=lambda m, b: profiles[m].runtime(b))

    # the swap actually happened, in both, with identical epoch + remap
    assert len(tr_sim.swaps) == 1
    assert tr_sim.swaps == tr_srv.swaps
    assert tr_sim.swaps[0][0] == 1            # epoch tag
    assert res.plan_swaps == server.plan_swaps
    assert res.plan_swaps[0][2] == "qps-exceeds-range"
    assert lc_sim.active.plan is new_plan and lc_srv.active.plan is new_plan

    # swap-inclusive decision-trace equality, element for element
    assert tr_sim.routes == tr_srv.routes
    assert tr_sim.gear_switches == tr_srv.gear_switches
    assert tr_sim.fires == tr_srv.fires
    assert tr_sim.hops == tr_srv.hops

    # in-flight work admitted before the swap finished on the OLD plan's
    # gear objects (epoch tagging): requests from the first phase resolved
    # under epoch 0
    by_epoch = {}
    for r in done:
        by_epoch.setdefault(r.plan_epoch, 0)
        by_epoch[r.plan_epoch] += 1
    assert by_epoch.get(0, 0) > 0 and by_epoch.get(1, 0) > 0
    assert res.completed == len(done)


def test_baseline_policy_runs_on_real_runtime(bert_like_profiles):
    """MS+ (a baseline built for the simulator) served by CascadeServer via
    the shared GearSelector protocol."""
    from repro.core.plan_state import HardwareSpec
    from repro.serving.baselines import MSPlusPolicy

    profiles = bert_like_profiles
    hw = HardwareSpec(num_devices=2, mem_per_device=16e9)
    slo = SLO(kind="latency", latency_p95=0.4)
    plan, selector = MSPlusPolicy(n_ranges=4).build_plan(
        profiles, hw, slo, qps_max=2000.0)
    trace = np.concatenate([np.full(3, 50.0), np.full(3, 1800.0)])
    n_arr = len(trace_to_arrivals(trace))
    engines = {m: _ReplayEngine(profiles[m].validation.certs)
               for m in profiles}
    server = CascadeServer(plan, engines, estimator=_cert_estimator,
                           selector=selector)
    reqs = [Request(rid=i, tokens=np.array([i], np.int64))
            for i in range(n_arr)]
    done = server.run_virtual(
        reqs, trace, batch_runtime=lambda m, b: profiles[m].runtime(b))
    assert len(done) >= 0.9 * n_arr
    assert len(server.gear_switches) >= 1    # the policy actually switched

    # the same policy on the simulator sees the same gear sequence
    gears, sel, reps, nd = MSPlusPolicy(n_ranges=4).build(
        profiles, hw, slo, 2000.0)
    r_sim = ServingSimulator(profiles, reps, nd).run_policy(
        gears, sel, trace)
    assert [g for _, g in r_sim.gear_switches] == \
        [g for _, g in server.gear_switches]


# ---------------------------------------------------------------------------
# Unit coverage of the four decision functions
# ---------------------------------------------------------------------------

def _core(reps, **cfg_kw):
    return SchedulerCore(reps, SchedulerConfig(**cfg_kw))


def test_route_follows_load_fractions(bert_like_profiles):
    reps = [Replica("tiny", 0, 1e-3), Replica("tiny", 1, 1e-3)]
    g = make_gear(Cascade(("tiny",), ()), reps,
                  load_fractions={"tiny": {0: 0.25, 1: 0.75}})
    core = _core(reps)
    picks = [core.route("tiny", g, u) for u in np.linspace(0.001, 0.999, 200)]
    frac0 = picks.count(0) / len(picks)
    assert 0.2 < frac0 < 0.3
    # deterministic in u
    assert core.route("tiny", g, 0.1) == core.route("tiny", g, 0.1)
    with pytest.raises(RuntimeError):
        core.route("nope", g, 0.5)


def test_hysteresis_holds_downgrade_until_drained():
    sel = with_hysteresis(lambda t, q, cur, q0: 0, alpha=8.0)
    # large backlog: hold the current (fast) gear
    assert sel(0.0, 100.0, 2, 1000) == 2
    # backlog drained: allow the downgrade
    assert sel(0.0, 100.0, 2, 1) == 0
    # upgrades are never held
    up = with_hysteresis(lambda t, q, cur, q0: 3, alpha=8.0)
    assert up(0.0, 100.0, 1, 10 ** 6) == 3


def test_select_gear_clamps_and_records():
    reps = [Replica("a", 0, 1e-3)]
    tr = DecisionTrace()
    core = SchedulerCore(reps, SchedulerConfig(),
                         selector=lambda t, q, cur, q0: 99, trace=tr)
    assert core.select_gear(0.0, 10.0, 0, 0, n_gears=3) == 2
    assert tr.gear_switches == [(0, 2)]


def test_should_fire_trigger_and_timeout():
    reps = [Replica("a", 0, 1e-3)]
    g = make_gear(Cascade(("a",), ()), reps, {"a": 4})
    core = _core(reps, max_wait=0.05)
    assert not core.should_fire(0, 99.0, "a", g)          # empty queue
    assert not core.should_fire(3, 0.01, "a", g)          # below trigger
    assert core.should_fire(4, 0.0, "a", g)               # trigger reached
    assert core.should_fire(1, 0.05, "a", g)              # HOL timeout
    # the comparison is EXACT (no epsilon fudge): a wait one ulp short of
    # max_wait does not fire — drivers snap the wait to max_wait at the
    # scheduled deadline float via scheduling.head_of_line_wait instead
    assert not core.should_fire(1, 0.05 - 1e-12, "a", g)
    assert core.should_fire(1, head_of_line_wait(1.05, 1.0, 0.05), "a", g)


def test_next_hop_threshold_semantics():
    reps = [Replica("a", 0, 1e-3), Replica("b", 0, 1e-2)]
    g = make_gear(Cascade(("a", "b"), (0.5,)), reps)
    core = _core(reps)
    hop = core.next_hop(0, 0.3, g)
    assert isinstance(hop, CascadeHop)
    assert hop.next_model == "b" and hop.next_stage == 1
    assert isinstance(core.next_hop(0, 0.5, g), Resolved)   # at threshold
    last = core.next_hop(1, 0.0, g)                         # final stage
    assert isinstance(last, Resolved) and last.stage == 1


def test_recover_restarts_stranded_queues():
    """A device that recovers after traffic stops must immediately restart
    the work stranded on its replicas (no arrival or timeout is coming to
    poll it during the drain)."""
    from repro.core.profiles import synthetic_family
    profiles = synthetic_family(["a", "b"], base_runtime=2e-4,
                                runtime_ratio=3.0, base_acc=0.7,
                                acc_gain=0.06, seed=3)
    reps = [Replica(m, d, profiles[m].runtime_per_sample(1.0))
            for d in range(2) for m in profiles]
    g = make_gear(Cascade(("a", "b"), (0.3,)), reps)
    plan = GearPlan(qps_max=500.0, gears=[g], replicas=reps, num_devices=2,
                    slo=SLO(kind="latency", latency_p95=1.0))
    sim = ServingSimulator(profiles, plan.replicas, 2)
    trace = np.full(8, 30.0)
    # fail mid-trace, recover during the drain: every head-of-line timeout
    # armed for the stranded samples has already fired as a no-op
    ev = [(2.0, 0, "fail", 0.0), (9.0, 0, "recover", 1.0)]
    r = sim.run_trace(plan, trace, device_events=ev, drain=3.0)
    assert r.completed == r.offered
    assert r.backlog_end == 0


def test_build_plan_rejects_ensemble_gears(bert_like_profiles):
    """Cocktail+ gears majority-vote; CascadeServer has no voting path, so
    packaging them for the real runtime must fail loudly, not silently
    serve only the first member."""
    from repro.core.plan_state import HardwareSpec
    from repro.serving.baselines import CocktailPlusPolicy
    hw = HardwareSpec(num_devices=2, mem_per_device=16e9)
    with pytest.raises(NotImplementedError):
        CocktailPlusPolicy().build_plan(
            bert_like_profiles, hw, SLO(kind="latency", latency_p95=0.4),
            1000.0)


def test_plan_target_matches_plan(bert_like_profiles):
    reps, plan, _ = _setup(bert_like_profiles)
    tgt = plan_target(plan)
    for qps in (0.0, 150.0, 399.0, 10_000.0):
        assert tgt(0.0, qps, 0, 0) == plan.gear_index_for_qps(qps)
