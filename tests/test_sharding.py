"""Sharding rules: param logical axes, sanitisation, cache layouts."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, get_smoke_config
from repro.distributed.context import DistContext
from repro.distributed import sharding as sh
from repro.models import model as M

CTX = DistContext(mesh=None, batch_axes=("data",))
CTX_POD = DistContext(mesh=None, batch_axes=("pod", "data"))


def _axes_of(params, *path):
    axes = sh.param_logical_axes(params)
    node = axes
    for k in path:
        node = node[k]
    return node


def test_param_rules_dense():
    cfg = get_smoke_config("qwen3-32b")
    params = M.init_params(cfg, spec_only=True)
    assert _axes_of(params, "embed", "embedding") == ("vocab", "fsdp")
    assert _axes_of(params, "embed", "lm_head") == ("fsdp", "vocab")
    # stacked block leaves get a leading None for the layer dim
    assert _axes_of(params, "blocks", 0, "attn", "wq") == \
        (None, "fsdp", "heads")
    assert _axes_of(params, "blocks", 0, "attn", "wo") == \
        (None, "heads", "fsdp")
    assert _axes_of(params, "blocks", 0, "ffn", "w_down") == \
        (None, "ffn", "fsdp")


def test_param_rules_expert_vs_shared():
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    params = M.init_params(cfg, spec_only=True)
    # routed experts: EP over the data axis, TP over ffn — no extra fsdp
    assert _axes_of(params, "blocks", 0, "moe", "w_gate") == \
        (None, "ep", None, "ffn")
    # the shared expert is a plain dense FFN
    assert _axes_of(params, "blocks", 0, "moe", "shared", "w_gate") == \
        (None, "fsdp", "ffn")


def test_param_rules_mamba():
    cfg = get_smoke_config("falcon-mamba-7b")
    params = M.init_params(cfg, spec_only=True)
    assert _axes_of(params, "blocks", 0, "mamba", "in_proj") == \
        (None, "fsdp", "d_inner")
    assert _axes_of(params, "blocks", 0, "mamba", "out_proj") == \
        (None, "d_inner", "fsdp")
    assert _axes_of(params, "blocks", 0, "mamba", "A_log") == \
        (None, "d_inner", None)


def test_fsdp_only_in_train_mode():
    spec_train = sh.logical_pspec(("fsdp", "heads"), CTX, "train")
    spec_serve = sh.logical_pspec(("fsdp", "heads"), CTX, "serve")
    assert spec_train == P("data", "model")
    assert spec_serve == P(None, "model")


def test_batch_axes_multipod():
    spec = sh.logical_pspec(("batch", None), CTX_POD, "train")
    assert spec == P(("pod", "data"), None)


def test_sanitize_pspec():
    mesh = None

    class FakeMesh:
        shape = {"data": 16, "model": 16, "pod": 2}
    # kv=2 heads can't shard over model=16 -> dropped
    out = sh.sanitize_pspec((24, 128, 32768, 2, 64),
                            P(None, "data", None, "model", None), FakeMesh())
    assert out == P(None, "data", None, None, None)
    # batch 1 can't shard over data -> dropped; 32768 % 16 == 0 stays
    out2 = sh.sanitize_pspec((1, 32768), P("data", "model"), FakeMesh())
    assert out2 == P(None, "model")
    # tuple axes: ('pod','data') = 32-way on batch 256 stays
    out3 = sh.sanitize_pspec((256, 10), P(("pod", "data"), None), FakeMesh())
    assert out3 == P(("pod", "data"), None)


def test_cache_layouts():
    cfg = get_smoke_config("qwen3-32b")
    cache = M.init_cache(cfg, batch=4, cache_len=64, spec_only=True)
    axes = sh.cache_logical_axes(cache)
    k_axes = axes["blocks"][0]["k"]
    assert k_axes == (None, "batch", None, "kv_heads", None)
    axes_seq = sh.cache_logical_axes(cache, seq_sharded=True)
    assert axes_seq["blocks"][0]["k"] == (None, "batch", "kv_seq", None, None)


def test_tree_bytes():
    tree = {"a": jnp.zeros((2, 3), jnp.bfloat16),
            "b": jnp.zeros((4,), jnp.float32)}
    assert sh.tree_bytes(tree) == 2 * 3 * 2 + 4 * 4
