"""Token-level serving decision layer + token DES + KV-aware planning
(DESIGN.md §13): StreamingCertainty, ContinuousBatcher, TokenProfile /
TokenReplayBackend, ServingSimulator.run_token_trace, and the planner's
KV-slot memory / slot-stability verdicts."""
import numpy as np
import pytest

from repro.core.cascade import Cascade, CascadeEval
from repro.core.certainty import StreamingCertainty
from repro.core.execution import TokenReplayBackend
from repro.core.gears import SLO, Gear
from repro.core.lp import Replica
from repro.core.plan_state import (HardwareSpec, InfeasiblePlanError,
                                   PlannerState)
from repro.core.profiles import synthetic_family, synthetic_token_family
from repro.core.scheduling import (CascadeHop, ContinuousBatcher, Resolved,
                                   SchedulerConfig, SchedulerCore)
from repro.core.simulator import ServingSimulator, SimConfig
from repro.core.submodules.batching import _slot_stability_error
from repro.core.submodules.hardware_mapping import solve_joint_placement


# ---------------------------------------------------------------------------
# StreamingCertainty
# ---------------------------------------------------------------------------

def test_streaming_certainty_folds():
    ewma = StreamingCertainty(mode="ewma", beta=0.5)
    assert ewma.value == 0.0                      # before any token
    ewma.update(0.8)
    assert ewma.value == pytest.approx(0.8)       # first token seeds
    ewma.update(0.4)
    assert ewma.value == pytest.approx(0.8 + 0.5 * (0.4 - 0.8))

    mean = StreamingCertainty(mode="mean")
    for g in (0.2, 0.4, 0.9):
        mean.update(g)
    assert mean.value == pytest.approx(np.mean([0.2, 0.4, 0.9]))

    mn = StreamingCertainty(mode="min")
    for g in (0.5, 0.1, 0.7):
        mn.update(g)
    assert mn.value == pytest.approx(0.1)

    with pytest.raises(ValueError):
        StreamingCertainty(mode="median")


# ---------------------------------------------------------------------------
# ContinuousBatcher
# ---------------------------------------------------------------------------

def _core(max_batch=16):
    return SchedulerCore([Replica("a", 0, 1e-3), Replica("b", 1, 2e-3)],
                         SchedulerConfig(max_batch=max_batch))


def test_continuous_batcher_admit():
    cb = ContinuousBatcher(_core(max_batch=3), n_slots=4)
    assert cb.admit(0, 10) == 3          # capped by max_batch
    assert cb.admit(2, 10) == 2          # capped by free slots
    assert cb.admit(1, 1) == 1           # capped by waiting
    assert cb.admit(4, 10) == 0          # full
    assert cb.admit(0, 0) == 0           # nothing waiting
    with pytest.raises(ValueError):
        ContinuousBatcher(_core(), n_slots=0)
    with pytest.raises(ValueError):
        ContinuousBatcher(_core(), n_slots=4, min_tokens=0)
    with pytest.raises(ValueError):
        ContinuousBatcher(_core(), n_slots=4, early_margin=1.5)


def test_continuous_batcher_boundary_hop():
    gear = Gear(cascade=Cascade(("a", "b"), (0.6,)),
                min_queue_lens={"a": 1, "b": 1},
                load_fractions={"a": {0: 1.0}, "b": {1: 1.0}})
    cb = ContinuousBatcher(_core(), n_slots=4, min_tokens=4,
                           early_margin=0.5)
    # mid-stream, before min_tokens: never hops regardless of certainty
    assert cb.boundary_hop(0, 0.0, 3, 10, gear) is None
    # mid-stream, low certainty (< thr * margin = 0.3): escalates NOW
    hop = cb.boundary_hop(0, 0.2, 5, 10, gear)
    assert isinstance(hop, CascadeHop) and hop.next_model == "b"
    # mid-stream, certainty above the early margin: keeps decoding
    assert cb.boundary_hop(0, 0.4, 5, 10, gear) is None
    # end of stream: the standard cascade rule decides
    assert isinstance(cb.boundary_hop(0, 0.4, 10, 10, gear), CascadeHop)
    assert isinstance(cb.boundary_hop(0, 0.9, 10, 10, gear), Resolved)
    # last stage resolves even when uncertain
    assert isinstance(cb.boundary_hop(1, 0.0, 10, 10, gear), Resolved)


# ---------------------------------------------------------------------------
# TokenProfile + TokenReplayBackend
# ---------------------------------------------------------------------------

def test_token_profile_family_and_runtime():
    toks = synthetic_token_family(["s", "l"], seed=0)
    assert set(toks) == {"s", "l"}
    p = toks["s"]
    n = p.validation_n
    assert p.gen_len.shape == (n,) and p.correct.shape == (n,)
    assert p.gaps.shape[0] == n and p.gen_len.max() <= p.gaps.shape[1]
    assert p.kv_bytes_per_slot > 0
    # per-STEP runtime: flat below the grid, interpolated inside,
    # marginal-slope extrapolation above
    bs = p.decode_batch_sizes
    rt = p.decode_step_runtimes
    assert p.decode_step_runtime(bs[0] / 2) == pytest.approx(rt[0])
    mid = (bs[0] + bs[1]) / 2.0
    lo, hi = p.decode_step_runtime(bs[0]), p.decode_step_runtime(bs[1])
    assert lo <= p.decode_step_runtime(mid) <= hi
    beyond = p.decode_step_runtime(bs[-1] * 2)
    assert beyond > p.decode_step_runtime(bs[-1])
    assert p.prefill_runtime(100) == pytest.approx(p.prefill_per_token * 100)
    # larger cascade members cost more per decode step
    assert toks["l"].decode_step_runtime(1) > toks["s"].decode_step_runtime(1)


def test_token_replay_backend():
    toks = synthetic_token_family(["s"], seed=1)
    be = TokenReplayBackend(toks)
    n = toks["s"].validation_n
    assert be.models() == ["s"]
    assert be.gen_len("s", 3) == int(toks["s"].gen_len[3])
    assert be.gen_len("s", 3 + n) == be.gen_len("s", 3)   # sid wraps
    g = be.token_gap("s", 5, 2)
    assert g == pytest.approx(float(toks["s"].gaps[5, 2]))
    assert be.correct("s", 7) == bool(toks["s"].correct[7])
    assert be.kv_bytes_per_slot("s") == toks["s"].kv_bytes_per_slot
    # runtime memo returns identical floats for identical batch sizes
    assert be.decode_step_runtime("s", 8) == be.decode_step_runtime("s", 8)
    with pytest.raises(ValueError):
        TokenReplayBackend({})


# ---------------------------------------------------------------------------
# Token DES: continuous batching vs static rebatching
# ---------------------------------------------------------------------------

def _token_scenario():
    toks = synthetic_token_family(["s", "l"], base_step=2e-4,
                                  step_ratio=3.0, seed=7)
    backend = TokenReplayBackend(toks)
    gear = Gear(cascade=Cascade(("s", "l"), (0.55,)),
                min_queue_lens={"s": 1, "l": 1},
                load_fractions={"s": {0: 1.0}, "l": {1: 1.0}},
                decode_slots={"s": 8, "l": 8},
                kv_bytes_per_slot={m: toks[m].kv_bytes_per_slot
                                   for m in toks})
    sim = ServingSimulator(synthetic_family(["s", "l"], seed=7),
                           [Replica("s", 0, 2e-4), Replica("l", 1, 6e-4)],
                           2, SimConfig(max_batch=16, max_wait=0.02))
    rng = np.random.default_rng(3)
    arrivals = np.cumsum(rng.exponential(1 / 150.0, size=250))
    plens = rng.integers(16, 128, size=250)
    return sim, gear, backend, arrivals, plens


def test_token_trace_continuous_beats_rebatch_iso_accuracy():
    sim, gear, backend, arrivals, plens = _token_scenario()
    cont = sim.run_token_trace(gear, arrivals, plens, backend,
                               mode="continuous", n_slots=8)
    reb = sim.run_token_trace(gear, arrivals, plens, backend,
                              mode="rebatch", n_slots=8)
    assert cont.completed == reb.completed == len(arrivals)
    assert cont.total_tokens > 0
    # shared escalation rule -> identical resolver decisions -> iso accuracy
    assert cont.accuracy == pytest.approx(reb.accuracy, abs=1e-12)
    np.testing.assert_array_equal(cont.resolver, reb.resolver)
    # the payoff: continuous batching strictly wins on token throughput
    # AND TTFT p95 (a forming batch no longer waits for the previous
    # batch's longest generation)
    assert cont.token_throughput > reb.token_throughput
    assert cont.ttft_p95() < reb.ttft_p95()


def test_token_trace_escalation_and_streams():
    sim, gear, backend, arrivals, plens = _token_scenario()
    res = sim.run_token_trace(gear, arrivals, plens, backend,
                              mode="continuous", n_slots=8)
    # the cascade actually escalates some streams to the large model
    assert 0 < (res.resolver == 1).sum() < res.completed
    # every completed stream emitted tokens and has ordered timestamps
    assert (res.tokens_out >= 1).all()
    assert (res.first_token >= res.arrive).all()
    assert (res.complete >= res.first_token).all()
    assert res.tpot_p95() >= 0.0
    with pytest.raises(ValueError):
        sim.run_token_trace(gear, arrivals, plens, backend, mode="magic")


# ---------------------------------------------------------------------------
# KV-slot memory as a placement constraint
# ---------------------------------------------------------------------------

def test_gear_kv_fields_and_serialization():
    g = Gear(cascade=Cascade(("s", "l"), (0.5,)),
             min_queue_lens={"s": 1, "l": 1},
             load_fractions={"s": {0: 1.0}, "l": {1: 1.0}},
             decode_slots={"s": 8}, kv_bytes_per_slot={"s": 2e7})
    assert g.kv_reserve("s") == pytest.approx(1.6e8)
    assert g.kv_reserve("l") == 0.0                # one-shot model
    rt = Gear.from_dict(g.to_dict())
    assert rt.decode_slots == g.decode_slots
    assert rt.kv_bytes_per_slot == g.kv_bytes_per_slot
    with pytest.raises(ValueError):
        Gear(cascade=Cascade(("s",), ()), min_queue_lens={"s": 1},
             load_fractions={"s": {0: 1.0}}, decode_slots={"s": 0})
    with pytest.raises(ValueError):
        Gear(cascade=Cascade(("s",), ()), min_queue_lens={"s": 1},
             load_fractions={"s": {0: 1.0}}, kv_bytes_per_slot={"s": -1.0})


def test_placement_rejects_kv_over_hbm():
    profs = synthetic_family(["s", "l"], seed=0)
    mem = max(profs[m].mem_bytes for m in profs)
    hw = HardwareSpec(num_devices=2, mem_per_device=1.5 * mem)
    wc = {"s": 50.0, "l": 10.0}
    base = solve_joint_placement(profs, hw, wc)
    assert base                                    # fits without KV
    # an empty reservation is the identical placement (bit-compatible)
    same = solve_joint_placement(profs, hw, wc, kv_reserve={})
    assert [(r.model, r.device) for r in same] == \
        [(r.model, r.device) for r in base]
    # slot memory the size of a device: nothing can fit -> rejected at
    # placement time, not discovered at runtime
    with pytest.raises(InfeasiblePlanError):
        solve_joint_placement(profs, hw, wc,
                              kv_reserve={m: hw.mem_per_device
                                          for m in profs})
    # a moderate reservation fits but leaves less room than weights-only
    fit = solve_joint_placement(profs, hw, wc,
                                kv_reserve={m: 0.2 * hw.mem_per_device
                                            for m in profs})
    assert len(fit) <= len(base)


# ---------------------------------------------------------------------------
# SP4: Little's-law decode-slot stability
# ---------------------------------------------------------------------------

def _slot_state(qps_max, decode_slots, residency, n_replicas):
    profs = synthetic_family(["s"], seed=0)
    state = PlannerState(
        profiles=profs,
        hardware=HardwareSpec(num_devices=max(n_replicas, 1),
                              mem_per_device=16e9),
        slo=SLO(kind="latency", latency_p95=1.0),
        qps_max=qps_max, n_ranges=1, qps_prior=np.array([1.0]))
    state.cascades = [Cascade(("s",), ())]
    state.cascade_evals = [CascadeEval(accuracy=0.9, fractions=(1.0,),
                                       avg_cost=1e-3)]
    state.assignment = [0]
    state.replicas = [Replica("s", d, 1e-3) for d in range(n_replicas)]
    state.decode_slots = dict(decode_slots)
    state.token_residency = dict(residency)
    return state


def test_slot_stability_littles_law():
    # demand: 100 qps * 0.5 s residency = 50 resident requests expected
    sat = _slot_state(100.0, {"s": 8}, {"s": 0.5}, n_replicas=2)
    err = _slot_stability_error(sat, 0)            # have 16 slots < 50
    assert err is not None and err.code == "throughput"
    assert err.model == "s" and "slots" in err.detail
    # enough replicas: 8 slots * 8 replicas = 64 >= 50 -> stable
    ok = _slot_state(100.0, {"s": 8}, {"s": 0.5}, n_replicas=8)
    assert _slot_stability_error(ok, 0) is None
    # one-shot plans (no slot/residency info) skip the check entirely
    oneshot = _slot_state(100.0, {}, {}, n_replicas=1)
    assert _slot_stability_error(oneshot, 0) is None
