"""Admission control (core/admission.py): downgrade, deadline shedding,
weighted-fair sharing — incl. the edge cases: zero-weight tenant, QPS
exactly on a range boundary, infeasible-cheapest-gear shedding, and
all-tenants-overloaded capacity conservation."""
import numpy as np
import pytest

from repro.core import SLO
from repro.core.admission import (AdmissionConfig, AdmissionController,
                                  cheapest_gear_index, fleet_capacities,
                                  gear_capacity, weighted_fair_shares)
from repro.core.cascade import Cascade
from repro.core.gears import GearPlan
from repro.core.lp import Replica
from repro.core.simulator import make_gear
from repro.core.tenancy import MultiTenantPlan, TenantSpec


def _mt_two_tenants(rt=1e-3, slo_a=None, slo_b=None, w_a=1.0, w_b=1.0,
                    qps_a=400.0, qps_b=400.0):
    """Two single-model tenants over 2 shared replicas of 'm' (fleet
    capacity = 2/rt samples/s, exactly computable)."""
    reps = [Replica("m", 0, rt), Replica("m", 1, rt)]
    slo_a = slo_a or SLO(kind="latency", latency_p95=0.5)
    slo_b = slo_b or SLO(kind="latency", latency_p95=0.5)
    specs = [TenantSpec("a", slo_a, qps_a, weight=w_a, n_ranges=1),
             TenantSpec("b", slo_b, qps_b, weight=w_b, n_ranges=1)]

    def plan(slo):
        return GearPlan(qps_max=qps_a, gears=[
            make_gear(Cascade(("m",), ()), reps)], replicas=reps,
            num_devices=2, slo=slo)

    return MultiTenantPlan(
        tenants=specs, plans={"a": plan(slo_a), "b": plan(slo_b)},
        gear_demand={"a": [{"m": 1.0}], "b": [{"m": 1.0}]})


def test_capacity_model():
    reps = [Replica("m", 0, 1e-3), Replica("m", 1, 2e-3),
            Replica("n", 0, 1e-2)]
    caps = fleet_capacities(reps)
    assert caps["m"] == pytest.approx(1500.0)
    assert caps["n"] == pytest.approx(100.0)
    # a cascade sending 10% of traffic to the slow model bottlenecks there
    assert gear_capacity({"m": 1.0, "n": 0.1}, caps) == pytest.approx(1000.0)
    assert gear_capacity({"m": 1.0}, caps) == pytest.approx(1500.0)


def test_cheapest_gear_prefers_higher_throughput():
    reps = [Replica("cheap", 0, 1e-3), Replica("heavy", 1, 1e-2)]
    g_heavy = make_gear(Cascade(("heavy",), ()), reps)
    g_cheap = make_gear(Cascade(("cheap",), ()), reps)
    plan = GearPlan(qps_max=100.0, gears=[g_heavy, g_cheap],
                    replicas=reps, num_devices=2,
                    slo=SLO(kind="latency", latency_p95=1.0))
    assert cheapest_gear_index(plan, [{"heavy": 1.0}, {"cheap": 1.0}]) == 1


# ---------------------------------------------------------------------------
# weighted-fair water-fill
# ---------------------------------------------------------------------------

def test_fair_shares_no_contention_everyone_keeps_need():
    alloc = weighted_fair_shares({"a": 0.3, "b": 0.4},
                                 {"a": 1.0, "b": 1.0})
    assert alloc == {"a": 0.3, "b": 0.4}


def test_fair_shares_all_overloaded_sum_to_capacity():
    needs = {"a": 2.0, "b": 1.5, "c": 3.0}
    weights = {"a": 2.0, "b": 1.0, "c": 1.0}
    alloc = weighted_fair_shares(needs, weights, capacity=1.0)
    assert sum(alloc.values()) == pytest.approx(1.0)
    # proportional when everyone stays unsatisfied
    assert alloc["a"] == pytest.approx(0.5)
    assert alloc["b"] == pytest.approx(0.25)
    assert alloc["c"] == pytest.approx(0.25)


def test_fair_shares_surplus_water_fills():
    # a needs little: its unused share flows to the others by weight
    alloc = weighted_fair_shares({"a": 0.1, "b": 5.0, "c": 5.0},
                                 {"a": 1.0, "b": 1.0, "c": 3.0})
    assert alloc["a"] == pytest.approx(0.1)
    assert alloc["b"] == pytest.approx(0.9 * 0.25)
    assert alloc["c"] == pytest.approx(0.9 * 0.75)
    assert sum(alloc.values()) == pytest.approx(1.0)


def test_fair_shares_zero_weight_is_best_effort():
    # zero-weight tenant gets nothing while weighted tenants are hungry...
    alloc = weighted_fair_shares({"a": 2.0, "z": 2.0},
                                 {"a": 1.0, "z": 0.0})
    assert alloc["a"] == pytest.approx(1.0)
    assert alloc["z"] == pytest.approx(0.0)
    # ...and only the leftover when they are not
    alloc2 = weighted_fair_shares({"a": 0.25, "z": 2.0},
                                  {"a": 1.0, "z": 0.0})
    assert alloc2["a"] == pytest.approx(0.25)
    assert alloc2["z"] == pytest.approx(0.75)


# ---------------------------------------------------------------------------
# controller edge cases (satellite checklist)
# ---------------------------------------------------------------------------

def test_boundary_qps_is_not_engaged():
    mt = _mt_two_tenants()
    ac = AdmissionController(mt)
    # sitting EXACTLY on qps_max is still inside the planned range
    d = ac.on_tick(0.1, {"a": 400.0, "b": 0.0}, {"a": 0, "b": 0})
    assert not d["a"].engaged
    assert not d["a"].force_cheapest
    assert d["a"].admit_fraction == 1.0
    # one epsilon beyond engages the downgrade
    d = ac.on_tick(0.2, {"a": 400.0 + 1e-6, "b": 0.0}, {"a": 0, "b": 0})
    assert d["a"].engaged and d["a"].force_cheapest


def test_disengage_needs_sustained_in_range_ticks():
    mt = _mt_two_tenants()
    ac = AdmissionController(mt, AdmissionConfig(disengage_ticks=3))
    ac.on_tick(0.1, {"a": 900.0, "b": 0.0}, {"a": 0, "b": 0})
    assert ac.decision("a").engaged
    for k in range(2):      # two in-range ticks: still held
        d = ac.on_tick(0.2 + k * 0.1, {"a": 100.0, "b": 0.0},
                       {"a": 0, "b": 0})
        assert d["a"].engaged
    d = ac.on_tick(0.5, {"a": 100.0, "b": 0.0}, {"a": 0, "b": 0})
    assert not d["a"].engaged


def test_zero_weight_tenant_is_shed_first_under_overload():
    # fleet capacity 2000; both tenants offer 2000 -> weighted tenant keeps
    # the fleet, zero-weight tenant is fully shed
    mt = _mt_two_tenants(rt=1e-3, w_a=1.0, w_b=0.0, qps_a=400.0,
                         qps_b=400.0)
    ac = AdmissionController(mt)
    d = ac.on_tick(0.1, {"a": 2000.0, "b": 2000.0}, {"a": 0, "b": 0})
    assert d["a"].admit_fraction == pytest.approx(1.0)
    assert d["b"].admit_fraction == pytest.approx(0.0, abs=1e-9)
    admitted_b = sum(ac.admit("b") for _ in range(100))
    assert admitted_b == 0
    assert ac.shed_counts["b"] == 100


def test_all_tenants_overloaded_split_sums_to_capacity():
    # capacity 2000 samples/s; both overloaded far beyond it: admitted
    # rates must sum to the fleet capacity (weighted 3:1), never above
    mt = _mt_two_tenants(rt=1e-3, w_a=3.0, w_b=1.0)
    ac = AdmissionController(mt)
    d = ac.on_tick(0.1, {"a": 4000.0, "b": 4000.0}, {"a": 0, "b": 0})
    admitted = {n: d[n].admit_fraction * 4000.0 for n in ("a", "b")}
    assert sum(admitted.values()) == pytest.approx(2000.0, rel=1e-6)
    assert admitted["a"] == pytest.approx(1500.0, rel=1e-6)
    assert admitted["b"] == pytest.approx(500.0, rel=1e-6)
    assert d["a"].engaged and d["b"].engaged


def test_shed_all_when_cheapest_gear_cannot_meet_latency_slo():
    # service time 50ms > SLO 10ms: no request can EVER meet the deadline
    mt = _mt_two_tenants(rt=5e-2,
                         slo_a=SLO(kind="latency", latency_p95=0.01))
    ac = AdmissionController(mt)
    d = ac.on_tick(0.1, {"a": 10.0, "b": 10.0}, {"a": 0, "b": 0})
    assert d["a"].shed_all
    assert d["a"].admit_fraction == 0.0
    assert not ac.admit("a")
    # tenant b's looser SLO (500ms) is servable
    assert not d["b"].shed_all
    assert ac.admit("b")
    # with deadline shedding disabled, the infeasible tenant is admitted
    ac2 = AdmissionController(mt, AdmissionConfig(deadline_shed=False))
    d2 = ac2.on_tick(0.1, {"a": 10.0, "b": 10.0}, {"a": 0, "b": 0})
    assert not d2["a"].shed_all and ac2.admit("a")


def test_credit_accumulator_spreads_sheds_deterministically():
    mt = _mt_two_tenants()
    ac = AdmissionController(mt)
    ac.on_tick(0.1, {"a": 4000.0, "b": 4000.0}, {"a": 0, "b": 0})
    frac = ac.decision("a").admit_fraction
    outcomes = [ac.admit("a") for _ in range(1000)]
    assert sum(outcomes) == pytest.approx(1000 * frac, abs=1)
    # deterministic: a fresh controller replays the identical sequence
    ac2 = AdmissionController(mt)
    ac2.on_tick(0.1, {"a": 4000.0, "b": 4000.0}, {"a": 0, "b": 0})
    assert [ac2.admit("a") for _ in range(1000)] == outcomes


def test_in_range_tenant_protected_during_neighbor_flash_crowd():
    # a spikes to 10x; b stays in range: b keeps full admission, a is
    # clamped to the residual capacity
    mt = _mt_two_tenants(rt=1e-3, qps_a=400.0, qps_b=400.0)
    ac = AdmissionController(mt)
    d = ac.on_tick(0.1, {"a": 4000.0, "b": 300.0}, {"a": 0, "b": 0})
    assert d["b"].admit_fraction == pytest.approx(1.0)
    assert not d["b"].force_cheapest
    a_admitted = d["a"].admit_fraction * 4000.0
    assert a_admitted == pytest.approx(2000.0 - 300.0, rel=1e-6)


def test_in_range_tenant_never_shed_even_at_low_weight():
    """An in-plan tenant's capacity is RESERVED, not fair-shared: a
    low-weight tenant inside its contract keeps full admission even when
    a high-weight neighbor's crowd would out-bid it in the water-fill
    (regression: fair-sharing over all tenants shed ~17% of b here)."""
    mt = _mt_two_tenants(rt=1e-3, w_a=3.0, w_b=1.0, qps_a=400.0,
                         qps_b=700.0)
    ac = AdmissionController(mt)
    d = ac.on_tick(0.1, {"a": 10000.0, "b": 600.0}, {"a": 0, "b": 0})
    assert not d["b"].engaged
    assert d["b"].admit_fraction == pytest.approx(1.0)
    # the engaged tenant receives exactly the residual capacity
    assert d["a"].admit_fraction * 10000.0 == \
        pytest.approx(2000.0 - 600.0, rel=1e-6)
