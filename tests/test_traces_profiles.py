"""Trace generators, profiles, cost model, and App. C.2 replanning."""
import numpy as np
import pytest

from repro.core.profiles import ModelProfile, ValidationRecord, \
    synthetic_family
from repro.core.traces import (azure_like_trace, diurnal_like_trace,
                               measured_qps_distribution, spiky_trace,
                               zipf_prior)


def test_zipf_prior_properties():
    p = zipf_prior(8)
    assert p.sum() == pytest.approx(1.0)
    assert (np.diff(p) < 0).all()  # low-QPS ranges are most frequent


@pytest.mark.parametrize("fn,peak", [(azure_like_trace, 60.0),
                                     (diurnal_like_trace, 7600.0)])
def test_traces_deterministic_and_scaled(fn, peak):
    a = fn(seconds=100, peak_qps=peak, seed=4)
    b = fn(seconds=100, peak_qps=peak, seed=4)
    np.testing.assert_array_equal(a, b)
    assert a.max() == pytest.approx(peak)
    assert (a >= 0).all()
    c = fn(seconds=100, peak_qps=peak, seed=5)
    assert not np.array_equal(a, c)


def test_traces_short_windows():
    # regression: generators must not crash on short windows
    assert len(azure_like_trace(seconds=10, peak_qps=10)) == 10
    assert len(diurnal_like_trace(seconds=10, peak_qps=10)) == 10


def test_spiky_trace_shape():
    t = spiky_trace(seconds=60, base_qps=100, spike_qps=1000, spike_len=5)
    assert t.max() == 1000
    assert np.median(t) == 100


def test_measured_distribution():
    trace = np.array([10.0] * 80 + [90.0] * 20)
    d = measured_qps_distribution(trace, 4, 100.0)
    assert d[0] == pytest.approx(0.8)
    assert d[3] == pytest.approx(0.2)


def test_profile_runtime_interpolation():
    p = ModelProfile(name="x", mem_bytes=1.0,
                     batch_sizes=np.array([1.0, 4.0, 16.0]),
                     batch_runtimes=np.array([1e-3, 2e-3, 6e-3]),
                     validation=ValidationRecord(certs=np.zeros(4),
                                                 correct=np.ones(4, bool)))
    assert p.runtime(1) == pytest.approx(1e-3)
    assert p.runtime(8) == pytest.approx(10e-3 / 3 )  # interp 4..16
    assert p.runtime(32) > p.runtime(16)  # extrapolates upward
    assert p.runtime_per_sample(16) < p.runtime_per_sample(1)  # batching wins
    d = p.to_dict()
    p2 = ModelProfile.from_dict(d)
    assert p2.runtime(8) == pytest.approx(p.runtime(8))


def test_cost_model_scales_sanely():
    from repro.configs import get_config
    from repro.profiling.cost_model import (analytic_runtime,
                                            min_slice_chips, model_flops)
    small = get_config("qwen2-0.5b")
    big = get_config("qwen3-32b")
    # bigger model: more flops, more chips, slower per step
    assert model_flops(big, 4096, 4096) > 10 * model_flops(small, 4096, 4096)
    assert min_slice_chips(big) > min_slice_chips(small)
    rt_s = analytic_runtime(small, 8, 2048, "decode", 1)
    rt_b = analytic_runtime(big, 8, 2048, "decode", min_slice_chips(big))
    assert rt_b > rt_s  # even on its slice, the 32B model is slower


def test_replan_with_measured_distribution():
    """App. C.2: deviation detection + replanning shifts accuracy toward
    the ranges the workload actually occupies."""
    from repro.core import HardwareSpec, SLO
    from repro.core.planner import (check_qps_distribution,
                                    optimize_gear_plan,
                                    replan_with_measured)
    from repro.core.traces import zipf_prior
    profiles = synthetic_family(["a", "b", "c"], base_runtime=2e-4,
                                runtime_ratio=2.5, seed=6)
    hw = HardwareSpec(num_devices=2, mem_per_device=16e9)
    slo = SLO(kind="latency", latency_p95=0.4)
    plan = optimize_gear_plan(profiles, hw, slo, qps_max=4000, n_ranges=4)
    # workload that lives at HIGH qps (anti-Zipf)
    trace = np.full(100, 3600.0)
    deviates, tv = check_qps_distribution(zipf_prior(4), trace, 4000.0)
    assert deviates and tv > 0.5
    replanned = replan_with_measured(profiles, hw, slo, 4000.0, trace,
                                     n_ranges=4)
    # the replanned top range is at least as accurate as the original's
    assert replanned.plan.gears[-1].expected_accuracy >= \
        plan.plan.gears[-1].expected_accuracy - 1e-9
