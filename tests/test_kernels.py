"""Pallas kernel validation (interpret mode) against the ref.py oracles —
shape/dtype sweeps per the assignment."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.mamba_scan import mamba_scan_pallas
from repro.kernels.top2gap import top2gap_pallas

RNG = np.random.default_rng(0)


def randf(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale, dtype)


# ---------------------------------------------------------------------------
# top2gap
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,v", [(1, 128), (4, 1000), (8, 512), (3, 4097),
                                 (16, 3157), (2, 50304)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_top2gap_sweep(b, v, dtype):
    x = randf((b, v), dtype, 3.0)
    gap, idx = top2gap_pallas(x, interpret=True)
    gref, iref = ops.top2gap_ref(x)
    np.testing.assert_allclose(np.asarray(gap), np.asarray(gref),
                               atol=1e-2 if dtype == jnp.bfloat16 else 1e-5)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(iref))


def test_top2gap_ties_and_blocks():
    # identical top-2 values across block boundaries
    x = np.zeros((2, 1024), np.float32)
    x[0, 5] = 7.0
    x[0, 700] = 7.0  # exact tie in another vocab block
    x[1, 1000] = 3.0
    x[1, 1] = 2.5
    gap, idx = top2gap_pallas(jnp.asarray(x), interpret=True)
    assert abs(float(gap[0])) < 1e-6
    assert abs(float(gap[1]) - 0.5) < 1e-6
    assert int(idx[1]) == 1000


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,hkv,s,d", [
    (2, 4, 2, 64, 32), (1, 8, 8, 96, 16), (2, 4, 1, 160, 64),
    (1, 2, 2, 33, 32),  # ragged seq (padding path)
])
def test_flash_attention_sweep(b, h, hkv, s, d):
    q = randf((b, h, s, d))
    k = randf((b, hkv, s, d))
    v = randf((b, hkv, s, d))
    out = flash_attention_pallas(q, k, v, block_q=32, block_k=32,
                                 interpret=True)
    ref = ops.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("window", [16, 48])
def test_flash_attention_sliding_window(window):
    b, h, s, d = 1, 4, 128, 32
    q, k, v = randf((b, h, s, d)), randf((b, 2, s, d)), randf((b, 2, s, d))
    out = flash_attention_pallas(q, k, v, window=window, block_q=32,
                                 block_k=32, interpret=True)
    ref = ops.flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_flash_attention_bf16():
    b, h, s, d = 2, 4, 64, 32
    q = randf((b, h, s, d), jnp.bfloat16)
    k = randf((b, 2, s, d), jnp.bfloat16)
    v = randf((b, 2, s, d), jnp.bfloat16)
    out = flash_attention_pallas(q, k, v, block_q=32, block_k=32,
                                 interpret=True)
    ref = ops.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=3e-2)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,hkv,c,d,vl", [
    (2, 8, 2, 256, 32, 100), (1, 4, 4, 64, 16, 64), (3, 16, 8, 640, 64, 639),
    (2, 4, 1, 100, 32, 1),   # single valid position
])
def test_decode_attention_sweep(b, h, hkv, c, d, vl):
    q = randf((b, h, d))
    k = randf((b, hkv, c, d))
    v = randf((b, hkv, c, d))
    out = decode_attention_pallas(q, k, v, jnp.asarray(vl), block_c=64,
                                  interpret=True)
    ref = ops.decode_attention_ref(q, k, v, jnp.asarray(vl))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.skipif(
    tuple(int(x) for x in jax.__version__.split(".")[:3]) < (0, 4, 37),
    reason="ragged (B,) valid_len in interpret-mode pallas needs the "
           f"per-row BlockSpec scalar path (jax {jax.__version__}; "
           "needs >= 0.4.37)")
@pytest.mark.parametrize("vl", [[100, 7, 256], [1, 64, 33]])
def test_decode_attention_ragged_batch(vl):
    """Per-row (B,) valid_len — the continuous-batching cache layout:
    matches the ref oracle AND per-row scalar calls (row independence)."""
    b, h, hkv, c, d = 3, 8, 2, 256, 32
    q = randf((b, h, d))
    k = randf((b, hkv, c, d))
    v = randf((b, hkv, c, d))
    vl_arr = jnp.asarray(vl, jnp.int32)
    out = decode_attention_pallas(q, k, v, vl_arr, block_c=64,
                                  interpret=True)
    ref = ops.decode_attention_ref(q, k, v, vl_arr)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
    for i in range(b):
        solo = decode_attention_pallas(q[i:i + 1], k[i:i + 1], v[i:i + 1],
                                       jnp.asarray(vl[i]), block_c=64,
                                       interpret=True)
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(solo[0]),
                                   atol=1e-6)


def test_decode_attention_valid_len_masks_garbage():
    b, h, hkv, c, d = 1, 4, 2, 128, 32
    q = randf((b, h, d))
    k = randf((b, hkv, c, d))
    v = randf((b, hkv, c, d))
    # poison the invalid region: result must not change
    k2 = k.at[:, :, 64:].set(1e4)
    v2 = v.at[:, :, 64:].set(-1e4)
    o1 = decode_attention_pallas(q, k, v, jnp.asarray(64), block_c=64,
                                 interpret=True)
    o2 = decode_attention_pallas(q, k2, v2, jnp.asarray(64), block_c=64,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-6)


# ---------------------------------------------------------------------------
# mamba scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,di,n,chunk", [
    (2, 64, 64, 8, 32), (1, 200, 128, 16, 64), (2, 33, 32, 4, 16),
])
def test_mamba_scan_sweep(b, s, di, n, chunk):
    dt = jnp.abs(randf((b, s, di))) * 0.1
    a = -jnp.abs(randf((di, n)))
    bm, cm = randf((b, s, n)), randf((b, s, n))
    dv = randf((di,))
    x = randf((b, s, di))
    y = mamba_scan_pallas(dt, a, bm, cm, dv, x, chunk=chunk, block_di=32,
                          interpret=True)
    yref, _ = ops.mamba_scan_ref(dt, a, bm, cm, dv, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref), atol=2e-4)


def test_mamba_scan_state_carries_across_chunks():
    """Same result regardless of chunking — the VMEM state must carry."""
    b, s, di, n = 1, 96, 32, 8
    dt = jnp.abs(randf((b, s, di))) * 0.2
    a = -jnp.abs(randf((di, n)))
    bm, cm = randf((b, s, n)), randf((b, s, n))
    dv = randf((di,))
    x = randf((b, s, di))
    y1 = mamba_scan_pallas(dt, a, bm, cm, dv, x, chunk=96, block_di=32,
                           interpret=True)
    y2 = mamba_scan_pallas(dt, a, bm, cm, dv, x, chunk=16, block_di=32,
                           interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)


def test_ops_wrappers_jit():
    """The jit'd public wrappers run end to end."""
    gap, idx = ops.top2gap(randf((4, 512)))
    assert gap.shape == (4,)
    out = ops.flash_attention(randf((1, 2, 32, 16)), randf((1, 2, 32, 16)),
                              randf((1, 2, 32, 16)), block_q=16, block_k=16)
    assert out.shape == (1, 2, 32, 16)
