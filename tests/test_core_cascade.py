"""Cascade semantics + certainty estimation, incl. hypothesis properties."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.cascade import (Cascade, enumerate_model_orderings,
                                evaluate_cascade, run_cascade_on_scores)
from repro.core.certainty import (CERTAINTY_ESTIMATORS, threshold_grid,
                                  top2_gap)
from repro.core.profiles import (ModelProfile, ValidationRecord,
                                 synthetic_family)


def test_eq5_top2_gap():
    import jax.numpy as jnp
    scores = jnp.asarray([[1.0, 5.0, 3.0], [0.0, 0.0, 0.0]])
    gap = top2_gap(scores)
    assert float(gap[0]) == 2.0
    assert float(gap[1]) == 0.0


def test_single_model_cascade_equals_model(bert_like_profiles):
    for name, prof in bert_like_profiles.items():
        ev = evaluate_cascade(Cascade((name,), ()), bert_like_profiles)
        assert ev.accuracy == pytest.approx(prof.accuracy)
        assert ev.fractions == (1.0,)


def test_zero_threshold_never_forwards(bert_like_profiles):
    c = Cascade(("tiny", "base"), (0.0,))
    ev = evaluate_cascade(c, bert_like_profiles)
    # certs are >= 0 -> everything resolves at the first model
    assert ev.fractions[1] == 0.0
    assert ev.accuracy == pytest.approx(
        bert_like_profiles["tiny"].accuracy)


def test_huge_threshold_always_forwards(bert_like_profiles):
    c = Cascade(("tiny", "base"), (1e9,))
    ev = evaluate_cascade(c, bert_like_profiles)
    assert ev.fractions[1] == 1.0
    assert ev.accuracy == pytest.approx(
        bert_like_profiles["base"].accuracy)


def test_cascade_beats_small_costs_less_than_big(bert_like_profiles):
    """The Fig. 1 story: a good cascade ~ big-model accuracy, lower cost."""
    grid = threshold_grid(bert_like_profiles["tiny"].validation.certs)
    best = None
    for t in grid:
        ev = evaluate_cascade(Cascade(("tiny", "base"), (float(t),)),
                              bert_like_profiles)
        if best is None or ev.accuracy > best.accuracy:
            best = ev
    base = bert_like_profiles["base"]
    assert best.accuracy >= base.accuracy - 0.01
    assert best.avg_cost < base.runtime_per_sample(1.0)


@given(st.lists(st.floats(0.0, 1.0), min_size=2, max_size=2))
@settings(max_examples=25, deadline=None)
def test_fractions_monotone_in_threshold(ths):
    """Forwarded fraction is monotone non-decreasing in the threshold."""
    profiles = synthetic_family(["a", "b"], seed=0, n_val=512)
    lo, hi = sorted(ths)
    ev_lo = evaluate_cascade(Cascade(("a", "b"), (lo,)), profiles)
    ev_hi = evaluate_cascade(Cascade(("a", "b"), (hi,)), profiles)
    assert ev_hi.fractions[1] >= ev_lo.fractions[1] - 1e-12


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=20, deadline=None)
def test_fractions_decrease_along_cascade(seed):
    profiles = synthetic_family(["a", "b", "c"], seed=seed % 1000, n_val=256)
    rng = np.random.default_rng(seed)
    ths = tuple(sorted(rng.uniform(0, 0.8, 2), reverse=True))
    ev = evaluate_cascade(Cascade(("a", "b", "c"), ths), profiles)
    assert ev.fractions[0] == 1.0
    assert all(ev.fractions[i + 1] <= ev.fractions[i] + 1e-12
               for i in range(2))
    # cost is the fraction-weighted sum of per-model costs
    manual = sum(f * profiles[m].runtime_per_sample(1.0)
                 for f, m in zip(ev.fractions, ("a", "b", "c")))
    assert ev.avg_cost == pytest.approx(manual)


def test_run_cascade_on_scores_matches_eval():
    """Online execution on raw scores == offline replay on records."""
    rng = np.random.default_rng(0)
    n, v = 512, 8
    scores = {m: rng.standard_normal((n, v)) * (1 + i)
              for i, m in enumerate(["s", "l"])}
    labels = rng.integers(0, v, n)
    import jax.numpy as jnp
    profiles = {}
    for m, sc in scores.items():
        certs = np.asarray(top2_gap(jnp.asarray(sc)))
        profiles[m] = ModelProfile(
            name=m, mem_bytes=1.0, batch_sizes=np.array([1.0]),
            batch_runtimes=np.array([1e-3 if m == "s" else 5e-3]),
            validation=ValidationRecord(certs=certs,
                                        correct=sc.argmax(-1) == labels))
    c = Cascade(("s", "l"), (0.8,))
    preds, resolver, _ = run_cascade_on_scores(c, scores)
    online_acc = (preds == labels).mean()
    ev = evaluate_cascade(c, profiles)
    assert online_acc == pytest.approx(ev.accuracy)
    assert (resolver == 1).mean() == pytest.approx(ev.fractions[1])


def test_orderings_by_cost(bert_like_profiles):
    order = enumerate_model_orderings(bert_like_profiles)
    costs = [bert_like_profiles[m].runtime_per_sample(1.0) for m in order]
    assert costs == sorted(costs)


def test_estimators_registry():
    import jax.numpy as jnp
    x = jnp.asarray(np.random.default_rng(0).standard_normal((4, 16)))
    for name, fn in CERTAINTY_ESTIMATORS.items():
        out = fn(x)
        assert out.shape == (4,), name
